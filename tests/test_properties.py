"""Hypothesis property tests on the system's invariants."""
import math

import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency (pip install .[dev])")

import hypothesis.strategies as st          # noqa: E402
import jax                                  # noqa: E402
import jax.numpy as jnp                     # noqa: E402
import numpy as np                          # noqa: E402
from hypothesis import given, settings      # noqa: E402

from repro.core import isa
from repro.core.opcount import OpCounts, count_fn
from repro.core.predict import predict
from repro.core.table import EnergyTable
from repro.hlo.parse import shape_bytes

TABLE = EnergyTable(system="t", p_const=40.0, p_static=50.0,
                    direct={"add.f32": 1e-11, "dot.bf16": 1.3e-12,
                            "hbm.read": 4.5e-11, "hbm.write": 5e-11,
                            "vmem.read": 1.4e-12, "vmem.write": 1.7e-12,
                            "exp.f32": 3e-11})
from repro.core import coverage as cov
cov.compute_bucket_means(TABLE)


@given(st.text(alphabet="abcdefghij._", min_size=1, max_size=24))
def test_group_class_idempotent(name):
    g1 = isa.group_class(name)
    assert isa.group_class(g1) == g1


@given(st.sampled_from(list(isa.CLASS_BY_NAME)))
def test_every_table_class_has_a_bucket(cls):
    assert isa.bucket_of(cls) in isa.ALL_BUCKETS


@given(st.floats(1.0, 1e6), st.floats(0.01, 100.0))
@settings(max_examples=30)
def test_prediction_linear_in_units(units, dur):
    c1 = OpCounts()
    c1.add("add.f32", units)
    c2 = c1.scaled(3.0)
    p1 = predict(TABLE, c1, dur, counters={})
    p2 = predict(TABLE, c2, dur, counters={})
    assert math.isclose(p2.dynamic_j, 3 * p1.dynamic_j, rel_tol=1e-9)
    assert math.isclose(p2.const_j, p1.const_j, rel_tol=1e-12)


@given(st.floats(0.1, 1e4))
@settings(max_examples=20)
def test_prediction_const_static_linear_in_time(dur):
    c = OpCounts()
    c.add("dot.bf16", 1e9)
    p = predict(TABLE, c, dur, counters={})
    assert math.isclose(p.const_j, TABLE.p_const * dur, rel_tol=1e-9)
    assert math.isclose(p.static_j, TABLE.p_static * dur, rel_tol=1e-9)


@given(st.integers(1, 4), st.integers(1, 64), st.integers(1, 64),
       st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_dot_macs_invariant(b, m, n, k):
    def fn(a_, b_):
        return jnp.einsum("bij,bjk->bik", a_, b_)
    c = count_fn(fn, jax.ShapeDtypeStruct((b, m, k), jnp.float32),
                 jax.ShapeDtypeStruct((b, k, n), jnp.float32))
    assert c.units["dot.f32"] == b * m * n * k
    assert c.flops == 2 * b * m * n * k


@given(st.integers(1, 40), st.integers(1, 2048))
@settings(max_examples=25, deadline=None)
def test_scan_count_multiplication_invariant(length, width):
    def fn(x):
        def body(carry, _):
            return carry * 1.5 + 2.0, ()
        c, _ = jax.lax.scan(body, x, None, length=length)
        return c
    c = count_fn(fn, jax.ShapeDtypeStruct((width,), jnp.float32))
    assert c.units["mul.f32"] == length * width
    assert c.units["add.f32"] == length * width


@given(st.sampled_from(["f32", "bf16", "s32", "u8", "pred", "f8e4m3fn"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_shape_bytes_parser(dtype, dims):
    s = f"{dtype}[{','.join(map(str, dims))}]"
    per = {"f32": 4, "bf16": 2, "s32": 4, "u8": 1, "pred": 1,
           "f8e4m3fn": 1}[dtype]
    want = per * int(np.prod(dims)) if dims else per
    assert shape_bytes(s) == want


@given(st.integers(0, 2))
def test_gen_classes_monotone(gen):
    c0 = {c.name for c in isa.classes_for_gen(gen)}
    c1 = {c.name for c in isa.classes_for_gen(gen + 1)}
    assert c0 <= c1


@given(st.floats(1e3, 1e9), st.floats(1e3, 1e9), st.floats(0.0, 1e9))
@settings(max_examples=30)
def test_opcounts_merge_additive(a_units, b_units, bbytes):
    x = OpCounts()
    x.add("add.f32", a_units)
    x.add_io(bbytes, bbytes / 2, 0.0)
    y = OpCounts()
    y.add("add.f32", b_units)
    z = OpCounts()
    z.merge(x)
    z.merge(y)
    assert math.isclose(z.units["add.f32"], a_units + b_units, rel_tol=1e-12)
    assert math.isclose(z.boundary_bytes, 1.5 * bbytes, rel_tol=1e-12)


# ---------------------------------------------------------------------------
# Array-backed currency invariants (the PR-3 vectorization).
# ---------------------------------------------------------------------------
_CLASS_NAMES = st.sampled_from(sorted(isa.CLASS_BY_NAME))
_UNIT_DICTS = st.dictionaries(_CLASS_NAMES, st.floats(1e-3, 1e9),
                              min_size=0, max_size=12)


@given(_UNIT_DICTS)
@settings(max_examples=40)
def test_opcounts_round_trip_through_dict_view(d):
    c = OpCounts(units=d)
    assert dict(c.units.items()) == {k: v for k, v in d.items() if v != 0.0}
    back = OpCounts(units=dict(c.units.items()))
    n = len(isa.CLASS_INDEX)
    np.testing.assert_array_equal(back.vector(n), c.vector(n))
    assert back.units == c.units


@given(_UNIT_DICTS, _UNIT_DICTS, st.floats(0.0, 1e4))
@settings(max_examples=40)
def test_merge_mult_equals_elementwise_arithmetic(da, db, mult):
    x, y = OpCounts(units=da), OpCounts(units=db)
    n = len(isa.CLASS_INDEX)
    want = x.vector(n) + y.vector(n) * mult
    z = x.scaled(1.0)
    z.merge(y, mult)
    np.testing.assert_array_equal(z.vector(n), want)


_ENERGY_DICTS = st.dictionaries(_CLASS_NAMES, st.floats(0.0, 1e-10),
                                min_size=0, max_size=16)
_BUCKETS = st.dictionaries(st.sampled_from(list(isa.ALL_BUCKETS)),
                           st.floats(1e-13, 1e-10), max_size=4)


@given(_ENERGY_DICTS, _ENERGY_DICTS, _BUCKETS)
@settings(max_examples=40)
def test_table_lookup_parity_dict_view_vs_vector_path(direct, scaled, bums):
    """The array-backed table's resolved vectors agree with per-class
    ``lookup`` for every interned class, in both modes — including explicit
    zero entries (hits) and bucket-mean fallbacks."""
    from repro.core.table import DIRECT as D
    t = EnergyTable(system="p", p_const=1.0, p_static=2.0, direct=direct,
                    scaled=scaled, bucket_means=bums)
    assert dict(t.direct.items()) == direct
    n = len(isa.CLASS_INDEX)
    e_direct, e_pred = t.energy_vectors(n)
    for i in range(n):
        cls = isa.CLASS_INDEX.name(i)
        v, how = t.lookup(cls, mode="pred")
        assert e_pred[i] == v
        assert e_direct[i] == (v if how == D else 0.0)


@given(st.lists(st.tuples(_UNIT_DICTS, st.floats(0.01, 100.0)),
                min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_predict_batch_bitwise_equals_per_program_loop(jobs):
    from repro.core.predict import TablePredictor
    predictor = TablePredictor(TABLE)
    counts = [OpCounts(units=d) for d, _ in jobs]
    durs = [dur for _, dur in jobs]
    loop = [predictor.predict(c, t, counters={}) for c, t in zip(counts, durs)]
    batch = predictor.predict_batch(counts, durs, [{}] * len(jobs))
    for a, b in zip(loop, batch):
        assert a.total_j == b.total_j          # bitwise, not approx
        assert a.dynamic_j == b.dynamic_j
        assert a.coverage == b.coverage
        assert a.by_class == b.by_class


@given(st.lists(st.integers(min_value=1, max_value=41),
                min_size=1, max_size=12),
       st.integers(min_value=2, max_value=6))
@settings(max_examples=40, deadline=None)
def test_kernel_tiling_bitwise_under_random_chunking(chunk_sizes, n_kids):
    """Kernel windows tile their step bitwise no matter how the sample
    stream is chunked — including chunks that straddle child boundaries."""
    from repro.telemetry import Marker, PowerSample, StreamAligner
    from repro.hw.device import SensorTrace

    n = 120
    t = np.arange(n) / 10.0                     # t = 0 .. 11.9
    p = 150.0 + 30.0 * np.sin(np.arange(n) / 5.0)
    trace = SensorTrace(t, p, np.ones(n), np.full(n, 50.0))
    parent = Marker(0, "step", 0.0, 10.0)
    edges = np.linspace(0.0, 10.0, n_kids + 1)
    kids = []
    cursor = parent.t_start_s
    for i in range(n_kids):                     # chain ends bit-for-bit
        end = parent.t_end_s if i == n_kids - 1 else float(edges[i + 1])
        kids.append(Marker(0, f"k{i}", cursor, end))
        cursor = end

    ref = StreamAligner()
    ref.add_marker(parent, list(kids))
    for ti, pi in zip(t, p):
        ref.add_sample(PowerSample(float(ti), float(pi)))
    (want,) = ref.close()
    assert sum(c.measured_j for c in want.children) == want.measured_j

    al = StreamAligner()
    al.add_marker(parent, list(kids))
    lo, i = 0, 0
    while lo < n:
        size = chunk_sizes[i % len(chunk_sizes)]
        al.add_samples(t[lo:lo + size], p[lo:lo + size])
        lo += size
        i += 1
    (got,) = al.close()
    assert got.measured_j == want.measured_j
    assert sum(c.measured_j for c in got.children) == got.measured_j
    for a, b in zip(got.children, want.children):
        assert a.measured_j == b.measured_j


# ---------------------------------------------------------------------------
# chaos layer: injected faults vs reported counters, seed determinism
# ---------------------------------------------------------------------------
from repro.hw.device import SensorTrace                          # noqa: E402
from repro.telemetry.faults import (ChaosPlan, FaultySampler,    # noqa: E402
                                    StreamSanitizer)
from repro.telemetry.sampler import TraceReplaySampler           # noqa: E402


def _chaos_trace(n):
    """Strictly increasing t and p (p well under the sensor bound), so
    every repeat, reorder, or non-finite value is injected, not native."""
    t = 0.01 * np.arange(1, n + 1)
    p = 100.0 + 1e-4 * np.arange(n)
    return SensorTrace(t, p, np.full(n, 0.5), np.full(n, 40.0))


_plans = st.builds(
    ChaosPlan,
    seed=st.integers(0, 2**32 - 1),
    drop_fraction=st.floats(0.0, 0.1),
    nan_fraction=st.floats(0.0, 0.05),
    nan_burst=st.integers(1, 4),
    spike_fraction=st.floats(0.0, 0.05),
    stale_fraction=st.floats(0.0, 0.05),
    stale_run=st.integers(1, 3),
    dup_fraction=st.floats(0.0, 0.02),
    swap_fraction=st.floats(0.0, 0.02),
    granularity=st.sampled_from([256, 1000, 4096]),
)


@given(_plans, st.integers(500, 4000), st.sampled_from([64, 256, 1024]))
@settings(max_examples=25, deadline=None)
def test_sanitizer_counters_match_chaos_report_exactly(plan, n, chunk):
    fs = FaultySampler(TraceReplaySampler(_chaos_trace(n)), plan)
    san = StreamSanitizer()
    kept = 0
    for t, p, u, c in fs.chunks(chunk):
        t2, *_ = san.chunk(t, p, u, c)
        kept += int(np.asarray(t2).size)
    rep = fs.report
    assert rep.samples_in == n
    assert san.total_in == rep.samples_out == n - rep.dropped
    want = rep.expected_quarantine
    assert san.quarantined_nonfinite == want["nonfinite"]
    assert san.quarantined_spike == want["spikes"]
    assert san.quarantined_out_of_order == want["out_of_order"]
    assert kept == rep.samples_out - san.quarantined
    assert san.stale_suspects == rep.stale_samples


@given(_plans, st.integers(500, 2000))
@settings(max_examples=15, deadline=None)
def test_chaos_report_deterministic_in_seed(plan, n):
    def one(chunk):
        fs = FaultySampler(TraceReplaySampler(_chaos_trace(n)), plan)
        sink = [np.asarray(t).copy() for t, _, _, _ in fs.chunks(chunk)]
        return fs.report.to_json(), sink
    ra, sa = one(128)
    rb, sb = one(512)
    assert ra == rb                       # identical report, byte for byte
    np.testing.assert_array_equal(np.concatenate(sa) if sa else np.empty(0),
                                  np.concatenate(sb) if sb else np.empty(0))


@given(st.integers(1, 4000), st.sampled_from([32, 256, 4096]))
@settings(max_examples=25, deadline=None)
def test_disabled_fault_layer_passthrough_bitwise(n, chunk):
    tr = _chaos_trace(n)
    fs = FaultySampler(TraceReplaySampler(tr), ChaosPlan.profile("none"))
    out = list(fs.chunks(chunk))
    ref = list(TraceReplaySampler(tr).chunks(chunk))
    assert len(out) == len(ref)
    for got, want in zip(out, ref):
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    assert fs.report.samples_in == 0      # identity path: nothing counted
