"""Teacher-forcing equivalence: decoding token-by-token through the cache
must reproduce the full-sequence forward logits — the strongest correctness
check on every cache implementation (KV, MLA latent, SSM state, hybrid)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import model as M

pytestmark = pytest.mark.slow   # heavy model/distributed tier

B, S = 2, 8

# f32 smoke variants for tight comparison
ARCHS = ["qwen2-0.5b", "gemma2-27b", "h2o-danube-3-4b", "minicpm3-4b",
         "mamba2-2.7b", "zamba2-2.7b", "arctic-480b", "qwen2-vl-7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(cfgs.get_smoke_config(arch), dtype="float32")
    if cfg.family == "vlm":
        # decode path uses pure text positions; compare on text-only batch
        cfg = dataclasses.replace(cfg, n_vision_tokens=0)
    if cfg.n_experts:
        # token-choice routing is batch-dependent through the capacity
        # limit; equivalence holds when nothing is dropped
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)

    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros((B, 0, cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    full_logits, _ = jax.jit(lambda p, b: M.forward(p, b, cfg))(params, batch)

    cache = M.init_cache(cfg, B, S + 1)
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    dec_logits = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1])
        dec_logits.append(lg[:, 0])
    dec = jnp.stack(dec_logits, axis=1)

    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = dataclasses.replace(cfgs.get_smoke_config("whisper-small"),
                              dtype="float32")
    from repro.models import encdec
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    enc_emb = jnp.asarray(
        rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)) * 0.02,
        jnp.float32)
    batch = {"tokens": tokens, "encoder_embeds": enc_emb}
    full_logits, _ = jax.jit(lambda p, b: M.forward(p, b, cfg))(params, batch)

    cache = M.init_cache(cfg, B, S + 1)
    ck, cv = encdec.prefill_cross_cache(params, enc_emb, cfg)
    cache = dict(cache, cross_k=ck, cross_v=cv)
    step = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)
