"""The frequency/DVFS axis: operating points on the device, the v3 table
family (migration, bitwise anchors, interpolation, sweep resume), and the
closed-loop sweet-spot governor."""
import json

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core import calibrate as cal
from repro.core.opcount import OpCounts
from repro.core.predict import TablePredictor
from repro.core.store import TableStore, migrate_table_dict
from repro.core.table import SCHEMA_VERSION, EnergyTable
from repro.dvfs import (GovernorConfig, SweetSpotGovernor, as_point, resolve)
from repro.telemetry import TelemetryService

SYSTEM = "sim-v5e-air"
FAST = dict(duration_s=3.0, repeats=2)     # throughput settings, not quality


def _counts() -> OpCounts:
    c = OpCounts()
    c.add("dot.bf16", 2e8)
    c.mxu_macs_total = c.mxu_macs_aligned = 2e8
    c.add("exp.f32", 1e6)
    c.add("add.f32", 5e6)
    c.boundary_read_bytes = 4e6
    c.boundary_write_bytes = 2e6
    c.naive_bytes = 8e6
    c.fused_bytes = 2e6
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


@pytest.fixture(scope="module")
def family(tmp_path_factory):
    """Anchor (nominal) + one low-frequency member, calibrated for real."""
    rd = tmp_path_factory.mktemp("dvfs_sweep")
    dev = cal.get_device(SYSTEM)
    cap = float(dev.chip.tdp_watts)
    extra = (float(dev.vf.f_min_mhz), cap)
    table = cal.calibrate_sweep(SYSTEM, points=[extra], run_dir=rd,
                                device=dev, **FAST)
    return table, extra, rd


# ---------------------------------------------------------------------------
# Device operating point.
# ---------------------------------------------------------------------------
def test_device_operating_point_roundtrip():
    dev = cal.get_device(SYSTEM)
    nom = dev.nominal_point
    assert nom.freq_mhz == dev.vf.f_nom_mhz
    dev.set_operating_point(dev.vf.f_min_mhz, power_cap_w=100.0)
    pt = dev.operating_point
    assert (pt.freq_mhz, pt.power_cap_w) == (dev.vf.f_min_mhz, 100.0)
    dev.reset_operating_point()
    assert dev.operating_point.freq_mhz == nom.freq_mhz


def test_as_point_forms():
    dev = cal.get_device(SYSTEM)
    assert as_point(None) is None
    assert as_point(700.0) == (700.0, None)
    assert as_point((700.0, 150.0)) == (700.0, 150.0)
    assert as_point([700.0, None]) == (700.0, None)
    dev.set_operating_point(700.0, power_cap_w=150.0)
    assert as_point(dev.operating_point) == (700.0, 150.0)
    dev.reset_operating_point()


# ---------------------------------------------------------------------------
# v2 -> v3 migration: old tables are a one-point family, bitwise intact.
# ---------------------------------------------------------------------------
def _v2_payload():
    return {
        "schema": 2,
        "system": SYSTEM,
        "p_const": 41.5,
        "p_static": 48.25,
        "direct": {"add.f32": 1e-11, "dot.bf16": 1.3e-12,
                   "exp.f32": 3.4e-11, "slice": 0.0},
        "scaled": {"vmem.write": 1.7e-12},
        "bucket_means": {"vpu_simple": 1e-11, "mxu": 1.3e-12},
        "meta": {"isa_gen": 0.0, "residual_rel": 0.01},
        "provenance": {"suite": "test"},
    }


def test_v2_migrates_to_one_point_family(tmp_path):
    store = TableStore(tmp_path)
    (tmp_path / f"{SYSTEM}__gen0__v2.json").write_text(
        json.dumps(_v2_payload()))

    table = store.get(SYSTEM)
    assert table is not None
    assert table.provenance["migrated_from_schema"] == 2
    assert table.points == {}                    # empty family ...
    assert len(table.family()) == 1              # ... = one-point family
    # republished under the v3 path
    assert json.loads(store.path_for(SYSTEM).read_text())["schema"] \
        == SCHEMA_VERSION

    # the one-point family answers ANY operating point with its anchor,
    # bitwise: legacy predictions are untouched by the new axis
    legacy = EnergyTable.from_dict(
        {k: v for k, v in _v2_payload().items()
         if k not in ("schema", "provenance")})
    pred, ref = TablePredictor(table), TablePredictor(legacy)
    c = _counts()
    for op in (None, 700.0, (1128.0, 215.0)):
        got = pred.predict(c, 5.0, operating_point=op)
        want = ref.predict(c, 5.0)
        assert got.total_j == want.total_j


def test_migrate_table_dict_v2_path():
    d = migrate_table_dict(_v2_payload())
    assert d["schema"] == SCHEMA_VERSION
    assert d["operating_points"] == []
    assert d["provenance"]["migrated_from_schema"] == 2
    assert d["provenance"]["suite"] == "test"


# ---------------------------------------------------------------------------
# Calibrated family: bitwise at anchors, linear between, clamped outside.
# ---------------------------------------------------------------------------
def test_family_anchors_are_bitwise(family):
    table, extra, _ = family
    c = _counts()
    fam_pred = TablePredictor(table)
    for f, cap, sub in table.family():
        via_family = fam_pred.predict(c, 5.0, operating_point=(f, cap))
        direct = TablePredictor(sub).predict(c, 5.0)
        assert via_family.total_j == direct.total_j, (f, cap)
        p_const, p_static = fam_pred.point_powers((f, cap))
        assert p_const == sub.p_const and p_static == sub.p_static


def test_none_path_equals_anchor_point(family):
    """Growing the family must not perturb the legacy (point=None) path."""
    table, _, _ = family
    pred = TablePredictor(table)
    c = _counts()
    anchor_pt = table.anchor_point()
    assert anchor_pt is not None
    assert pred.predict(c, 5.0).total_j \
        == pred.predict(c, 5.0, operating_point=anchor_pt).total_j


def test_interpolation_is_linear_and_clamped(family):
    table, (f_lo, cap), _ = family
    f_hi = table.anchor_point()[0]
    mid = 0.5 * (f_lo + f_hi)
    r = resolve(table, mid, cap)
    assert not r.exact
    lo, hi = r.lo, r.hi
    w = r.w
    ed, ep = r.vectors(8)
    ed0, ep0 = lo.energy_vectors(8)
    ed1, ep1 = hi.energy_vectors(8)
    np.testing.assert_array_equal(ed, ed0 * (1 - w) + ed1 * w)
    np.testing.assert_array_equal(ep, ep0 * (1 - w) + ep1 * w)
    assert r.p_const == lo.p_const * (1 - w) + hi.p_const * w

    # outside the calibrated span: clamp to the boundary member, exactly
    below = resolve(table, f_lo - 100.0, cap)
    above = resolve(table, f_hi + 100.0, cap)
    assert below.exact and below.lo is table.points[(f_lo, cap)]
    assert above.exact and above.lo is table


def test_family_survives_store_roundtrip(family, tmp_path):
    table, extra, _ = family
    store = TableStore(tmp_path)
    cal.publish(table, store)
    loaded = store.get(SYSTEM)
    assert loaded is not None
    assert set(loaded.points) == set(table.points)
    c = _counts()
    for f, cap, _sub in table.family():
        a = TablePredictor(table).predict(c, 5.0, operating_point=(f, cap))
        b = TablePredictor(loaded).predict(c, 5.0, operating_point=(f, cap))
        assert a.total_j == b.total_j


def test_sweep_resume_is_bitwise(family):
    table, extra, rd = family
    again = cal.calibrate_sweep(SYSTEM, points=[extra], run_dir=rd, **FAST)
    assert set(again.points) == set(table.points)
    for key in table.points:
        assert dict(again.points[key].direct.items()) \
            == dict(table.points[key].direct.items())
    assert again.p_const == table.p_const


# ---------------------------------------------------------------------------
# Governor: SLA filter, hysteresis, drift pause, workload-shift re-explore.
# ---------------------------------------------------------------------------
A, B = (564.0, 215.0), (940.0, 215.0)


def _feed(gov, point, j_per_work, work_per_s, times=1):
    for _ in range(times):
        gov.observe(point, measured_j=j_per_work * 10.0,
                    duration_s=10.0 / work_per_s, work_units=10.0)


def test_governor_explores_then_holds_best():
    gov = SweetSpotGovernor([A, B])
    p1 = gov.propose()
    _feed(gov, p1, 1.0, 50.0)
    p2 = gov.propose()
    _feed(gov, p2, 2.0, 200.0)
    assert {p1, p2} == {A, B}
    assert gov.best_measured() == A         # min J/work, no SLA
    # hysteresis: it dwells at the last-explored point until the floor is
    # met, then switches to the measured argmin and holds it
    while gov.propose() != A:
        assert gov.decisions[-1].reason == "hold"
        _feed(gov, gov.current, 2.0, 200.0)
        assert len(gov.decisions) < 10      # must converge quickly
    assert gov.decisions[-1].reason == "switch"
    _feed(gov, A, 1.0, 50.0)
    assert gov.propose() == A
    assert gov.decisions[-1].reason == "hold"


def test_governor_sla_excludes_slow_points():
    gov = SweetSpotGovernor([A, B], GovernorConfig(sla_work_per_s=100.0))
    _feed(gov, gov.propose(), 1.0, 50.0)    # A: cheapest but too slow
    _feed(gov, gov.propose(), 2.0, 200.0)   # B: meets the SLA
    assert gov.propose() == B
    assert gov.best_measured() == B
    # nothing meets the SLA -> fastest point, reason "sla"
    strict = SweetSpotGovernor([A, B], GovernorConfig(sla_work_per_s=1e9))
    _feed(strict, strict.propose(), 1.0, 50.0)
    _feed(strict, strict.propose(), 2.0, 200.0)
    assert strict.propose() == B            # fastest measured
    assert strict.decisions[-1].reason == "sla"


def test_governor_hysteresis_delays_switch():
    gov = SweetSpotGovernor([B, A],
                            GovernorConfig(hysteresis_windows=2,
                                           min_improvement=0.02,
                                           restale_tol=1e9))
    _feed(gov, gov.propose(), 1.0, 200.0)   # B first (explore order)
    _feed(gov, gov.propose(), 0.5, 100.0)   # A: 2x better
    # current is A already (last explored) -> best == current, holds
    assert gov.propose() == A
    # force current back to the worse point, dwell below the floor
    _feed(gov, A, 2.0, 100.0, times=1)      # A now looks worse than B
    gov._current, gov._dwell = A, 0
    assert gov.propose() == A               # dwell < hysteresis: no switch
    assert gov.decisions[-1].reason == "hold"
    _feed(gov, A, 2.0, 100.0, times=2)      # dwell reaches the floor
    assert gov.propose() == B
    assert gov.decisions[-1].reason == "switch"


def test_governor_drift_pause_freezes():
    drifting = [False]
    gov = SweetSpotGovernor([A, B], drift_flag=lambda: drifting[0])
    _feed(gov, gov.propose(), 1.0, 100.0)
    drifting[0] = True
    held = gov.propose()
    assert gov.decisions[-1].reason == "drift-pause"
    assert held == gov.current
    drifting[0] = False
    gov.propose()
    assert gov.decisions[-1].reason != "drift-pause"


def test_governor_reexplores_on_workload_shift():
    gov = SweetSpotGovernor([A, B], GovernorConfig(restale_tol=0.25))
    _feed(gov, gov.propose(), 1.0, 100.0)
    _feed(gov, gov.propose(), 2.0, 100.0)
    _feed(gov, B, 2.0, 100.0, times=2)      # dwell past the hysteresis floor
    assert gov.propose() == A               # converged on A
    _feed(gov, A, 1.05, 100.0)              # +5%: within tolerance
    assert gov.propose() == A
    _feed(gov, A, 3.0, 100.0)               # the mix shifted under it
    assert gov.propose() == B               # stats reset -> re-explore
    assert gov.decisions[-1].reason == "re-explore"


def test_governor_seeded_exploration_order():
    gov = SweetSpotGovernor([A, B])
    gov.seed_exploration(lambda p: {A: 2.0, B: 1.0}[p])
    assert gov.propose() == B               # best predicted first


def test_service_reports_governor():
    gov = SweetSpotGovernor([A, B])
    _feed(gov, gov.propose(), 1.0, 100.0)
    svc = TelemetryService()
    svc.register_governor("serve/test", gov)
    snap = svc.snapshot()
    g = snap["governors"]["serve/test"]
    assert g["current"]["freq_mhz"] in (A[0], B[0])
    json.dumps(snap)                        # JSON-safe end to end
    with pytest.raises(TypeError):
        svc.register_governor("bad", object())


# ---------------------------------------------------------------------------
# fork(): copy-on-repair isolation.
# ---------------------------------------------------------------------------
def test_fork_isolates_table_mutations():
    table = EnergyTable.from_dict(
        {k: v for k, v in _v2_payload().items()
         if k not in ("schema", "provenance")})
    model = EnergyModel(table, system=SYSTEM)
    forked = model.fork()
    c = _counts()
    before = model.predict(c, 5.0).total_j
    assert forked.predict(c, 5.0).total_j == before
    for cls in forked.table.direct:
        forked.table.direct[cls] *= 2.0
    assert model.predict(c, 5.0).total_j == before       # original intact
    assert forked.predict(c, 5.0).total_j != before
    assert forked.table is not model.table


# ---------------------------------------------------------------------------
# Closed loop over the real streaming pipeline (slow tail).
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_governed_run_end_to_end(family):
    table, extra, _ = family
    model = EnergyModel(table, system=SYSTEM)
    pts = [p for p, _, _ in
           ((table.anchor_point(), None, None), (extra, None, None))]
    gov = SweetSpotGovernor(pts)
    run = model.govern(_counts(), gov, rounds=5, steps=2,
                      work_units=64.0, min_duration_s=4.0)
    assert len(run.rounds) == 5
    assert run.final_point in pts
    # device restored after the governed run
    assert model.device.operating_point.freq_mhz \
        == model.device.vf.f_nom_mhz
