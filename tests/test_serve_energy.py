"""Serving-energy subsystem: ledger conservation, scheduler policy, billing.

Acceptance criteria covered here:
  (a) per-request measured (and predicted) energies tile each aligned
      step's total *bitwise*, across join/evict boundaries;
  (b) tenant bills sum bitwise to the run total;
  (c) the J/token budget caps decode-batch packing and drift sheds load.
Plus the satellites: ``greedy_generate`` attn_fn parity and jitted-step
reuse, and the ``TelemetryService`` billing snapshot.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.serve import (ActiveShare, ContinuousBatchingScheduler,
                         EnergyPolicy, LedgerPolicy, Request, RequestLedger,
                         bill_tenants, fold_residual, split_conserving,
                         synthetic_counts_fn)
from repro.telemetry import TelemetryService


@pytest.fixture(scope="module")
def model():
    return EnergyModel.from_store("sim-v5e-air")


def _lsum(parts):
    acc = 0.0
    for p in parts:
        acc += p
    return acc


# ---------------------------------------------------------------------------
# (a) split_conserving / fold_residual: the bitwise primitive.
# ---------------------------------------------------------------------------
def test_split_conserving_bitwise_unit():
    # regression: rounding-tie cases where a single residual carrier
    # 2-cycles forever (tie-to-even skips an odd-mantissa total)
    for total, w in [
        (100.6111111111111, [0.4663553184071367, 0.2668223407964317,
                             0.2668223407964317]),
        (46636804.646235056, [0.13, 0.87]),
        (289.84999999999997, [0.5, 0.3, 0.2]),
    ]:
        parts = split_conserving(total, w)
        assert _lsum(parts) == total


def test_split_conserving_edge_cases():
    assert split_conserving(0.0, []).size == 0
    with pytest.raises(ValueError):
        split_conserving(1.0, [])
    np.testing.assert_array_equal(split_conserving(3.7, [0.2]), [3.7])
    # degenerate weights fall back to an even split
    parts = split_conserving(10.0, [0.0, 0.0, 0.0, 0.0])
    assert _lsum(parts) == 10.0
    assert np.allclose(parts, 2.5)


def test_split_conserving_property_sweep():
    """Randomized property: conservation is bitwise and shares stay
    within ulps of proportional, across magnitudes, signs and sizes."""
    rng = np.random.default_rng(7)
    for _ in range(5000):
        n = int(rng.integers(1, 12))
        total = float(rng.uniform(1e-6, 1e6) * 10.0**int(rng.integers(-6, 6)))
        if rng.random() < 0.1:
            total = -total
        weights = rng.uniform(0.0, 1.0, n)
        if rng.random() < 0.05:
            weights[:] = 0.0
        parts = split_conserving(total, weights)
        assert _lsum(parts) == total
        wsum = weights.sum()
        if wsum > 0 and total != 0.0:
            ideal = total * weights / wsum
            assert np.max(np.abs(parts - ideal)) <= 16 * np.finfo(float).eps \
                * abs(total)


def test_fold_residual_reaches_total():
    rng = np.random.default_rng(3)
    for _ in range(2000):
        n = int(rng.integers(1, 8))
        parts = list(rng.uniform(0.0, 100.0, n))
        total = float(_lsum(parts) * (1.0 + rng.uniform(-1e-13, 1e-13)))
        assert _lsum(fold_residual(parts, total)) == total


# ---------------------------------------------------------------------------
# (a) ledger: per-step tiling across join/evict boundaries.
# ---------------------------------------------------------------------------
def _share(rid, tenant, tokens, kv):
    return ActiveShare(request_id=rid, tenant=tenant, tokens=tokens,
                       kv_bytes=kv)


def test_ledger_steps_tile_bitwise_across_membership_changes():
    ledger = RequestLedger()
    rng = np.random.default_rng(11)
    roster = [("r0", "a"), ("r1", "a"), ("r2", "b"), ("r3", "c")]
    for step in range(60):
        # churn membership every few steps: joins and evictions
        k = 1 + (step // 3) % len(roster)
        active = [_share(rid, t, tokens=float(rng.integers(1, 64)),
                         kv=float(rng.integers(0, 1 << 20)))
                  for rid, t in roster[:k]]
        rec = ledger.record_step(
            step=step, kind="decode" if step % 5 else "prefill",
            duration_s=0.1, measured_j=float(rng.uniform(1.0, 1e4)),
            predicted_j=float(rng.uniform(1.0, 1e4)),
            dynamic_frac=float(rng.uniform(0.0, 1.0)), active=active,
            work_scale=float(rng.integers(1, 9)))
        assert _lsum(e.measured_j for e in rec.entries) == rec.measured_j
        assert _lsum(e.predicted_j for e in rec.entries) == rec.predicted_j
    # roll-up totals account every joule of every step
    per_req = ledger.per_request()
    assert set(per_req) == {r for r, _ in roster}
    total_steps = sum(t.steps for t in per_req.values())
    assert total_steps == sum(s.batch for s in ledger.steps)


def test_ledger_policy_weight_blend():
    pol = LedgerPolicy(residency_frac=0.5)
    active = [_share("a", "t", tokens=3.0, kv=0.0),
              _share("b", "t", tokens=1.0, kv=1000.0)]
    # fully dynamic step: pure active-token share
    np.testing.assert_allclose(pol.weights(active, 1.0), [0.75, 0.25])
    # fully static step: residency/occupancy blend only
    np.testing.assert_allclose(pol.weights(active, 0.0), [0.25, 0.75])
    # residency_frac=0: static part is pure occupancy
    np.testing.assert_allclose(
        LedgerPolicy(residency_frac=0.0).weights(active, 0.0), [0.5, 0.5])
    with pytest.raises(ValueError):
        LedgerPolicy(residency_frac=1.5)


def test_ledger_rejects_empty_step():
    with pytest.raises(ValueError):
        RequestLedger().record_step(
            step=0, kind="decode", duration_s=0.1, measured_j=1.0,
            predicted_j=1.0, dynamic_frac=0.5, active=[])


# ---------------------------------------------------------------------------
# (b) billing: tenant bills re-conserve against run totals.
# ---------------------------------------------------------------------------
def test_tenant_bills_sum_bitwise_to_run_total():
    ledger = RequestLedger()
    rng = np.random.default_rng(23)
    tenants = ["acme", "bravo", "chi"]
    for step in range(40):
        active = [_share(f"r{i}", tenants[i % 3],
                         tokens=float(rng.integers(1, 8)),
                         kv=float(rng.integers(1, 1 << 16)))
                  for i in range(1 + step % 5)]
        ledger.record_step(step=step, kind="decode", duration_s=0.1,
                           measured_j=float(rng.uniform(10.0, 500.0)),
                           predicted_j=float(rng.uniform(10.0, 500.0)),
                           dynamic_frac=0.7, active=active,
                           work_scale=2.0)
    report = bill_tenants(ledger)
    assert _lsum(b.measured_j for b in report.bills.values()) == \
        ledger.measured_total_j
    assert _lsum(b.predicted_j for b in report.bills.values()) == \
        ledger.predicted_total_j
    assert list(report.bills) == sorted(report.bills)   # name order
    snap = report.snapshot()
    json.dumps(snap)                                    # JSON-safe
    assert snap["measured_total_j"] == ledger.measured_total_j


def test_billing_empty_ledger():
    report = bill_tenants(RequestLedger())
    assert report.bills == {}
    assert report.measured_total_j == 0.0


# ---------------------------------------------------------------------------
# (c) scheduler policy: pure logic with injected pricing/drift.
# ---------------------------------------------------------------------------
def _requests(n, tenant="t", prompt=8, new=4, arrivals=None):
    arrivals = arrivals or [0] * n
    return [Request(id=f"r{i}", tenant=tenant, prompt_len=prompt,
                    max_new=new, arrival_step=arrivals[i])
            for i in range(n)]


def _drain(sched):
    phases = []
    while (ph := sched.next_phase()) is not None:
        phases.append(ph)
        assert len(phases) < 500
    return phases


def test_budget_caps_batch_packing():
    # J/token rises with batch; budget only affords 2
    jpt = lambda b: 1.0 + 0.5 * (b - 1)
    sched = ContinuousBatchingScheduler(
        _requests(5), EnergyPolicy(max_batch=8, budget_j_per_token=1.6),
        j_per_token=jpt, drift_flag=lambda: False)
    phases = _drain(sched)
    assert max(p.batch for p in phases) == 2
    deferred = [e for e in sched.events if e.event == "defer"]
    assert deferred and "budget" in deferred[0].detail
    # every request still completes
    assert all(s.completed_step is not None for s in sched.slots.values())


def test_max_batch_and_fifo_admission():
    sched = ContinuousBatchingScheduler(
        _requests(6), EnergyPolicy(max_batch=3),
        j_per_token=lambda b: 1.0, drift_flag=lambda: False)
    phases = _drain(sched)
    assert max(p.batch for p in phases) == 3
    admits = [e.request_id for e in sched.events if e.event == "admit"]
    assert admits[:3] == ["r0", "r1", "r2"]            # arrival order


def test_starvation_guard_admits_first_request():
    # budget below even a batch-1 J/token: the first request must still run
    sched = ContinuousBatchingScheduler(
        _requests(2), EnergyPolicy(max_batch=4, budget_j_per_token=0.1),
        j_per_token=lambda b: 1.0, drift_flag=lambda: False)
    phases = _drain(sched)
    assert phases
    assert all(s.completed_step is not None for s in sched.slots.values())
    assert max(p.batch for p in phases) == 1


def test_drift_sheds_newest_request():
    flags = iter([False, False, True])   # drift appears at the 3rd boundary
    drifting = lambda: next(flags, False)
    sched = ContinuousBatchingScheduler(
        _requests(3, new=8), EnergyPolicy(max_batch=4, shed_on_drift=True),
        j_per_token=lambda b: 1.0, drift_flag=drifting)
    _drain(sched)
    shed = [e for e in sched.events if e.event == "shed"]
    assert len(shed) == 1
    rid = shed[0].request_id
    assert sched.slots[rid].sheds == 1
    # the shed request re-prefilled and still completed
    assert sched.slots[rid].completed_step is not None


def test_staggered_arrivals_and_idle_skip():
    sched = ContinuousBatchingScheduler(
        _requests(3, arrivals=[0, 2, 20]), EnergyPolicy(max_batch=4),
        j_per_token=lambda b: 1.0, drift_flag=lambda: False)
    phases = _drain(sched)
    # no phase spans an arrival boundary
    for ph in phases:
        for r in sched.slots.values():
            a = r.req.arrival_step
            assert not (ph.step0 < a < ph.step0 + ph.n_steps)
    assert any(e.event == "idle" for e in sched.events)


def test_prefill_phase_bills_stalled_residents():
    sched = ContinuousBatchingScheduler(
        _requests(2, arrivals=[0, 2], prompt=8, new=8),
        EnergyPolicy(max_batch=4),
        j_per_token=lambda b: 1.0, drift_flag=lambda: False)
    phases = _drain(sched)
    late_prefill = [p for p in phases if p.kind == "prefill" and p.batch == 2]
    assert late_prefill, "second prefill should include the resident request"
    shares = late_prefill[0].shares(0)
    by_id = {s.request_id: s for s in shares}
    assert by_id["r1"].tokens == 8.0          # the prefilling request
    assert by_id["r0"].tokens == 0.0          # stalled, pays residency only
    assert by_id["r0"].kv_bytes > 0.0


def test_duplicate_request_ids_rejected():
    reqs = _requests(2)
    reqs[1] = dataclasses.replace(reqs[1], id="r0")
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(
            reqs, EnergyPolicy(), j_per_token=lambda b: 1.0,
            drift_flag=lambda: False)


# ---------------------------------------------------------------------------
# End-to-end: EnergyServer on the simulated device.
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_report(model):
    service = TelemetryService()
    server = model.serve(
        synthetic_counts_fn(interference=0.3),
        policy=EnergyPolicy(max_batch=4), min_phase_seconds=2.0,
        service=service, name="test-serve",
        drift_flag=lambda: False)   # deterministic schedule for assertions
    reqs = [Request("r0", "acme", prompt_len=16, max_new=5, arrival_step=0),
            Request("r1", "acme", prompt_len=8, max_new=3, arrival_step=0),
            Request("r2", "zeta", prompt_len=4, max_new=6, arrival_step=2)]
    return server.run(reqs), service


def test_serve_run_conserves_bitwise(serve_report):
    report, _ = serve_report
    assert len(report.ledger) > 0
    for s in report.ledger.steps:
        assert _lsum(e.measured_j for e in s.entries) == s.measured_j
        assert _lsum(e.predicted_j for e in s.entries) == s.predicted_j
    assert _lsum(b.measured_j for b in report.billing.bills.values()) == \
        report.ledger.measured_total_j


def test_serve_report_requests_complete(serve_report):
    report, _ = serve_report
    by_id = {r.request.id: r for r in report.requests}
    assert by_id["r0"].generated == 5
    assert by_id["r1"].generated == 3
    assert by_id["r2"].generated == 6
    for r in report.requests:
        assert r.completed_step is not None
        assert r.measured_j > 0
        assert r.tokens == r.request.prompt_len + r.generated - 1


def test_serve_phases_match_ledger(serve_report):
    report, _ = serve_report
    by_step = {s.step: s for s in report.ledger.steps}
    for ph in report.phases:
        steps = [by_step[ph.step0 + i] for i in range(ph.n_steps)]
        # every ledger step in the phase carries the phase's work scale,
        # and the phase totals are the same floats summed in the same order
        assert all(s.work_scale == ph.work_scale >= 1.0 for s in steps)
        assert all(s.batch == ph.batch for s in steps)
        assert _lsum(s.measured_j for s in steps) == ph.measured_j
        assert _lsum(s.predicted_j for s in steps) == ph.predicted_j
    assert sum(ph.n_steps for ph in report.phases) == len(report.ledger)


def test_service_snapshot_carries_billing(serve_report):
    report, service = serve_report
    snap = service.snapshot()
    assert "billing" in snap
    bill = snap["billing"]["test-serve"]
    assert bill["measured_total_j"] == report.measured_total_j
    assert set(bill["billing"]["tenants"]) == {"acme", "zeta"}
    json.dumps(snap)                         # whole snapshot stays JSON-safe
    assert len(snap["sessions"]) == len(report.phases)


def test_report_snapshot_json_safe(serve_report):
    report, _ = serve_report
    snap = report.snapshot()
    text = json.dumps(snap)
    assert "acme" in text
    assert snap["steps"] == len(report.ledger)
    assert report.table().count("\n") >= len(report.requests)


def test_facade_serve_with_requests_returns_report(model):
    report = model.serve(
        synthetic_counts_fn(), min_phase_seconds=2.0,
        requests=[Request("q0", "t0", prompt_len=4, max_new=2)])
    assert report.requests[0].generated == 2
    for s in report.ledger.steps:
        assert _lsum(e.measured_j for e in s.entries) == s.measured_j


def test_serve_budget_enforced_on_device(model):
    server = model.serve(synthetic_counts_fn(interference=0.5),
                         min_phase_seconds=2.0)
    budget = server.predict_j_per_token(2) * 1.05
    capped = model.serve(
        synthetic_counts_fn(interference=0.5),
        policy=EnergyPolicy(max_batch=8, budget_j_per_token=budget),
        min_phase_seconds=2.0, drift_flag=lambda: False)
    reqs = [Request(f"r{i}", f"t{i % 2}", prompt_len=8, max_new=6)
            for i in range(4)]
    report = capped.run(reqs)
    assert max(p.batch for p in report.phases) == 2
    assert any(e.event == "defer" for e in report.events)
    assert all(r.completed_step is not None for r in report.requests)


# ---------------------------------------------------------------------------
# Satellites: greedy_generate attn_fn parity + jitted-step reuse.
# ---------------------------------------------------------------------------
def test_greedy_generate_attn_fn_and_jit_reuse():
    import jax
    import jax.numpy as jnp
    from repro import configs as cfgs
    from repro.kernels import ops
    from repro.models import model as M
    from repro.serve import step as serve_step

    cfg = dataclasses.replace(cfgs.get_smoke_config("qwen2-0.5b"),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.array([[5, 9, 2, 7]], jnp.int32)
    out_ref = serve_step.greedy_generate(params, cfg, prompt, max_new=4,
                                         max_seq=16)
    # attn_fn is accepted and forwarded; the cached decode path keeps the
    # reference attention, so results are unchanged
    out_flash = serve_step.greedy_generate(
        params, cfg, prompt, max_new=4, max_seq=16,
        attn_fn=ops.make_attn_fn(interpret=True))
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_flash))

    # one jitted step per (cfg, attn_fn), reused across calls
    s1 = serve_step.jitted_serve_step(cfg)
    s2 = serve_step.jitted_serve_step(cfg)
    assert s1 is s2
    assert serve_step.jitted_serve_step(
        dataclasses.replace(cfg, n_layers=cfg.n_layers)) is s1  # equal cfg
