"""The unified `EnergyModel` facade: store round-trips, batched prediction,
profile-source parity, and the deprecation shims."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.api import (Comparison, CountsSource, EnergyModel, HloSource,
                       JaxprSource, PredictJob, Profile)
from repro.core import predict as predict_mod
from repro.core.opcount import OpCounts, count_fn
from repro.core.store import TableStore
from repro.core.table import (SCHEMA_VERSION, EnergyTable, TableSchemaError)


def _table(system="sim-v5e-air"):
    return EnergyTable(
        system=system, p_const=40.0, p_static=50.0,
        direct={"add.f32": 1e-11, "mul.f32": 1.2e-11, "dot.bf16": 1.3e-12,
                "exp.f32": 3.4e-11, "tanh.f32": 4.2e-11,
                "hbm.read": 4.5e-11, "hbm.write": 5.2e-11,
                "vmem.read": 1.4e-12, "ici.all_reduce": 2.8e-11},
        scaled={"vmem.write": 1.7e-12},
        bucket_means={"vpu_simple": 1.05e-11, "vpu_trans": 3.8e-11,
                      "mxu": 1.3e-12, "move": 6e-12},
        meta={"isa_gen": 0.0})


def _fn(x, w):
    return jnp.sum(jnp.tanh(x @ w))


_ARGS = (jax.ShapeDtypeStruct((256, 128), jnp.bfloat16),
         jax.ShapeDtypeStruct((128, 64), jnp.bfloat16))


# ---------------------------------------------------------------------------
# Table schema + store round-trip.
# ---------------------------------------------------------------------------
def test_table_save_load_roundtrip(tmp_path):
    t = _table()
    path = tmp_path / "t.json"
    t.save(path)
    assert json.loads(path.read_text())["schema"] == SCHEMA_VERSION
    t2 = EnergyTable.load(path)
    assert t2 == t


def test_table_load_rejects_missing_or_wrong_schema(tmp_path):
    t = _table()
    path = tmp_path / "t.json"
    t.save(path)
    d = json.loads(path.read_text())
    del d["schema"]
    path.write_text(json.dumps(d))
    with pytest.raises(TableSchemaError, match="schema version"):
        EnergyTable.load(path)
    d["schema"] = SCHEMA_VERSION + 99
    path.write_text(json.dumps(d))
    with pytest.raises(TableSchemaError, match="schema version"):
        EnergyTable.load(path)


def test_table_load_rejects_unknown_keys(tmp_path):
    t = _table()
    path = tmp_path / "t.json"
    t.save(path)
    d = json.loads(path.read_text())
    d["surprise_field"] = 1
    path.write_text(json.dumps(d))
    with pytest.raises(TableSchemaError, match="surprise_field"):
        EnergyTable.load(path)


def test_store_roundtrip_and_keys(tmp_path):
    store = TableStore(tmp_path)
    assert store.get("sim-v5e-air") is None
    path = store.put(_table())
    assert path.name == f"sim-v5e-air__gen0__v{SCHEMA_VERSION}.json"
    got = store.get("sim-v5e-air")
    assert got == _table()
    assert store.keys() == [path.stem]
    assert store.entries()[path.stem] == {"isa_gen": 0,
                                          "schema": SCHEMA_VERSION}
    assert store.evict("sim-v5e-air") and store.get("sim-v5e-air") is None


def test_store_stale_schema_is_a_warned_miss(tmp_path):
    store = TableStore(tmp_path)
    path = store.put(_table())
    d = json.loads(path.read_text())
    d["schema"] = SCHEMA_VERSION + 1
    path.write_text(json.dumps(d))
    with pytest.warns(RuntimeWarning, match="unreadable energy table"):
        assert store.get("sim-v5e-air") is None  # warned miss, not a crash
    path.write_text("{not json")                 # corrupt file: same contract
    with pytest.warns(RuntimeWarning, match="unreadable energy table"):
        assert store.get("sim-v5e-air") is None


def test_store_get_or_train_trains_once(tmp_path):
    store = TableStore(tmp_path)
    calls = []

    def fake_train(system):
        calls.append(system)
        return _table(system)

    t1 = store.get_or_train("sim-v5e-air", fake_train)
    t2 = store.get_or_train("sim-v5e-air", fake_train)
    assert calls == ["sim-v5e-air"]              # second call hit the disk
    assert t1 == t2


def test_from_store_persists_across_sessions(tmp_path, monkeypatch):
    calls = []
    monkeypatch.setattr("repro.api.train_table",
                        lambda system, **kw: (calls.append(system),
                                              _table(system))[1])
    store = TableStore(tmp_path)
    m1 = EnergyModel.from_store("sim-v5e-air", store=store)
    m2 = EnergyModel.from_store("sim-v5e-air", store=store)   # "new process"
    assert calls == ["sim-v5e-air"]
    assert m1.table == m2.table
    with pytest.raises(KeyError):
        EnergyModel.from_store("sim-v5e-liquid", store=store,
                               train_if_missing=False)


# ---------------------------------------------------------------------------
# Batched prediction == N single predictions.
# ---------------------------------------------------------------------------
def test_predict_many_matches_per_workload_predict():
    from repro.workloads.suite import build_workloads
    model = EnergyModel(_table())
    wls = build_workloads(isa_gen=0)
    jobs = [PredictJob(wl.counts.scaled(7.0), 3.0 + i, name=wl.name)
            for i, wl in enumerate(wls)]
    batched = model.predict_many(jobs)
    assert len(batched) == len(wls)
    for job, got in zip(jobs, batched):
        ref = predict_mod.predict(model.table, job.source, job.duration_s)
        assert got.total_j == pytest.approx(ref.total_j, rel=1e-9)
        assert got.by_class == ref.by_class
        assert got.coverage == pytest.approx(ref.coverage, rel=1e-9)


def test_predict_semantics_hand_computed():
    # pins the accounting independently of the (shared) TablePredictor code:
    # direct hit + bucket-mean fallback + scaled entry + counter traffic
    model = EnergyModel(_table())
    counts = {"add.f32": 1e9,      # direct: 1e-11 J/unit
              "sub.f32": 2e9}      # miss -> vpu_simple bucket mean 1.05e-11
    counters = {"hbm_read_bytes": 1e10,    # direct: 4.5e-11 J/B
                "vmem_write_bytes": 1e9}   # scaled: 1.7e-12 J/B
    p = model.predict(model.profile_counts(counts), 2.0, counters=counters)
    assert p.const_j == pytest.approx(40.0 * 2)
    assert p.static_j == pytest.approx(50.0 * 2)
    assert p.by_class["add.f32"] == pytest.approx(0.01)
    assert p.by_class["sub.f32"] == pytest.approx(0.021)
    assert p.by_class["hbm.read"] == pytest.approx(0.45)
    assert p.by_class["vmem.write"] == pytest.approx(0.0017)
    assert p.dynamic_j == pytest.approx(0.4827)
    assert p.total_j == pytest.approx(180.4827)
    assert p.coverage == pytest.approx(0.46 / 0.4827)
    d = model.predict(model.profile_counts(counts), 2.0, counters=counters,
                      mode="direct")
    assert d.dynamic_j == pytest.approx(0.46)      # non-direct classes -> 0 J
    assert d.total_j == pytest.approx(180.46)
    assert d.coverage == pytest.approx(0.46 / 0.4827)


def test_predictor_invalidate_after_table_mutation():
    model = EnergyModel(_table())
    prof = model.profile_counts({"add.f32": 1e9})
    before = model.predict(prof, 0.0).by_class["add.f32"]
    model.table.direct["add.f32"] *= 2
    model.predictor.invalidate()
    after = model.predict(prof, 0.0).by_class["add.f32"]
    assert after == pytest.approx(2 * before)


def test_predict_many_mixed_modes_and_tuples():
    model = EnergyModel(_table())
    counts = count_fn(_fn, *_ARGS)
    direct, pred = model.predict_many(
        [PredictJob(counts, 1.0, mode="direct"), (counts, 1.0)])
    ref_direct = predict_mod.predict(model.table, counts, 1.0, mode="direct")
    ref_pred = predict_mod.predict(model.table, counts, 1.0, mode="pred")
    assert direct.total_j == pytest.approx(ref_direct.total_j, rel=1e-9)
    assert pred.total_j == pytest.approx(ref_pred.total_j, rel=1e-9)
    assert direct.dynamic_j <= pred.dynamic_j


# ---------------------------------------------------------------------------
# Profile sources.
# ---------------------------------------------------------------------------
def test_profile_source_parity_jaxpr_vs_raw_counts():
    model = EnergyModel(_table())
    via_jaxpr = model.profile(_fn, *_ARGS)
    raw = count_fn(_fn, *_ARGS, isa_gen=model.isa_gen)
    via_counts = model.profile_counts(raw)
    assert via_jaxpr.counts.units == via_counts.counts.units
    p1 = model.predict(via_jaxpr, 2.0)
    p2 = model.predict(via_counts, 2.0)
    p3 = model.predict(raw, 2.0)                 # bare OpCounts works too
    assert p1.total_j == pytest.approx(p2.total_j, rel=1e-12)
    assert p1.total_j == pytest.approx(p3.total_j, rel=1e-12)


def test_profile_counts_from_class_map():
    model = EnergyModel(_table())
    prof = model.profile_counts({"add.f32": 1e9, "exp.f32": 2e6})
    pred = model.predict(prof, 1.0)
    dyn_expected = 1e9 * 1e-11 + 2e6 * 3.4e-11
    assert pred.by_class["add.f32"] == pytest.approx(1e9 * 1e-11)
    assert pred.dynamic_j == pytest.approx(dyn_expected)


HLO = """
HloModule test, entry_computation_layout={()->f32[]}

%fused (p: f32[128,64]) -> f32[128,64] {
  %t = f32[128,64]{1,0} tanh(%p)
  ROOT %a = f32[128,64]{1,0} add(%t, %t)
}

ENTRY %main (x: f32[128,256], w: f32[256,64]) -> f32[] {
  %d = f32[128,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %f = f32[128,64]{1,0} fusion(%d), kind=kLoop, calls=%fused
  %ar = f32[128,64]{1,0} all-reduce(%f), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %r = f32[] reduce(%ar, %zero), dimensions={0,1}, to_apply=%add
}
"""


def test_profile_hlo_source():
    model = EnergyModel(_table())
    prof = model.profile_hlo(HLO)
    units = prof.counts.units
    assert units["tanh.f32"] == 128 * 64
    assert units["add.f32"] >= 128 * 64
    assert units["ici.all_reduce"] > 0
    assert prof.counts.fused_bytes > 0
    pred = model.predict(prof, 1.0)
    assert pred.dynamic_j > 0


def test_bare_callable_is_rejected_with_hint():
    model = EnergyModel(_table())
    with pytest.raises(TypeError, match="profile it first"):
        model.predict(_fn, 1.0)


# ---------------------------------------------------------------------------
# Measure / compare / monitor verbs.
# ---------------------------------------------------------------------------
def test_compare_measures_and_predicts():
    model = EnergyModel(_table())
    cmp = model.compare(_fn, *_ARGS, target_seconds=2.0)
    assert isinstance(cmp, Comparison)
    assert cmp.measured_j > 0 and cmp.predicted_j > 0
    assert cmp.record.duration_s > 0
    # prediction and measurement describe the same run
    assert cmp.prediction.duration_s == pytest.approx(cmp.record.duration_s)


def test_attribute_breakdown():
    model = EnergyModel(_table())
    pred = model.attribute(model.profile(_fn, *_ARGS), duration_s=1.0)
    assert sum(pred.by_bucket.values()) == pytest.approx(pred.total_j)
    assert pred.by_bucket["const"] == pytest.approx(40.0)


def test_monitor_shares_the_predictor():
    model = EnergyModel(_table())
    mon = model.monitor(window=4)
    assert mon._predictor is model.predictor
    counts = count_fn(_fn, *_ARGS)
    rec = mon.observe(0, counts, 0.1)
    assert rec.prediction.total_j > 0


def test_evaluate_explicit_table_overrides_model():
    from repro.core.evaluate import evaluate_system
    from repro.workloads.suite import Workload
    model = EnergyModel(_table())
    wl = Workload(name="w", counts=count_fn(_fn, *_ARGS), family="ml",
                  target_seconds=1.0)
    hybrid = _table()
    for k in hybrid.direct:
        hybrid.direct[k] *= 3.0
    kw = dict(workloads=[wl], with_accelwattch=False, with_guser=False)
    rep_model = evaluate_system("sim-v5e-air", model=model, **kw)
    rep_hybrid = evaluate_system("sim-v5e-air", model=model, table=hybrid,
                                 **kw)
    # the hybrid table (3x energies) must actually be the one evaluated
    assert (rep_hybrid.results[0].predictions["wattchmen_pred"]
            > rep_model.results[0].predictions["wattchmen_pred"])


# ---------------------------------------------------------------------------
# Kernel microscopy / autotuning facades.
# ---------------------------------------------------------------------------
def test_microscope_tiles_and_reports():
    model = EnergyModel(_table())
    prof = model.profile(_fn, *_ARGS)
    rep = model.microscope([("matmul", prof), ("tanh", prof, "ref")],
                           steps=3, recalibrate=None)
    assert rep.tiling_exact
    assert set(rep.kernels) >= {"matmul", "tanh"}
    assert rep.kernels["tanh"]["variant"] == "ref"
    assert rep.kernels["matmul"]["energy_j"] > 0
    # per-kernel energies (plus the unattributed filler) recompose the
    # attributed total — the microscope's whole point
    assert sum(d["energy_j"] for d in rep.kernels.values()) == pytest.approx(
        rep.attributed_j, rel=1e-9)
    with pytest.raises(ValueError, match="at least one launch"):
        model.microscope([])


def test_microscope_dict_launches_and_step_counts():
    model = EnergyModel(_table())
    prof = model.profile(_fn, *_ARGS)
    rep = model.microscope(
        [{"name": "fa", "source": prof, "variant": "pallas",
          "config": (256, 256)}],
        steps=2, step_counts=prof, recalibrate=None)
    assert rep.tiling_exact
    assert rep.kernels["fa"]["config"] == [256, 256]


def test_tune_kernel_facade_persists_and_activates(tmp_path):
    from repro.kernels import autotune
    store = TableStore(tmp_path)
    model = EnergyModel(_table())
    try:
        res = model.tune_kernel("ssd_chunked", store=store,
                                durations=(2.0, 4.0), repeats=(1, 1))
        assert res.winner.j_per_op <= res.default.j_per_op
        kt = store.get_kernel_table("sim-v5e-air")
        assert kt is not None and kt.get(*res.winner.key) is not None
        # measurement records land under the store, resumable by design
        assert list((tmp_path / "runs" / "sim-v5e-air__kernels"
                     / "records").glob("*.json"))
        assert autotune.best_config("ssd_chunked") == res.winner.config
    finally:
        autotune.set_active(None)


# ---------------------------------------------------------------------------
# Deprecation shims.
# ---------------------------------------------------------------------------
def test_cached_table_shim_warns_and_uses_store(tmp_path, monkeypatch):
    from repro.core import trainer
    monkeypatch.setenv("REPRO_TABLE_STORE", str(tmp_path))
    TableStore(tmp_path).put(_table())
    with pytest.warns(DeprecationWarning, match="from_store"):
        # bypass the lru memo: the shim body must hit the on-disk store
        got = trainer.cached_table.__wrapped__("sim-v5e-air")
    assert got == _table()


def test_engine_imports_still_work():
    # the old engine surface stays importable (shimmed, not removed)
    from repro.core.predict import predict          # noqa: F401
    from repro.core.trainer import cached_table, train_table  # noqa: F401
    from repro.core.measure import total_energy     # noqa: F401


def test_top_level_lazy_exports():
    import repro
    assert repro.EnergyModel is EnergyModel
    assert repro.EnergyTable is EnergyTable
    assert "TableStore" in dir(repro)
    with pytest.raises(AttributeError):
        repro.does_not_exist
