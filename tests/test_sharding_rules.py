"""Logical-axis sharding rules: divisibility-aware degradation."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.layers import PSpec
from repro.parallel import act_sharding, sharding as sh

# a fake 16x16 mesh without devices: use jax.sharding.Mesh over abstract?
# simplest: build a small real mesh and scale expectations to it.


@pytest.fixture(scope="module")
def mesh():
    # single-device CPU: mesh of 1x1 still exercises the rule logic for
    # divisibility via axis sizes of 1; use AbstractMesh for 16x16 shapes
    return sh.abstract_mesh((16, 16), ("data", "model"))


def test_ff_goes_to_model(mesh):
    spec = PSpec((4864, 896), ("ff", "embed"))
    assert sh.spec_to_pspec(spec, mesh) == P("model", "data")


def test_indivisible_heads_replicate(mesh):
    spec = PSpec((896, 14, 64), ("embed", "q_heads", "head_dim"))
    # 14 heads % 16 != 0 -> replicated; embed 896 % 16 == 0 -> fsdp(data)
    assert sh.spec_to_pspec(spec, mesh) == P("data", None, None)


def test_odd_vocab_replicates(mesh):
    spec = PSpec((51865, 768), ("vocab", "embed"))
    # 51865 = 5*11*23*41: neither model nor data divide it
    assert sh.spec_to_pspec(spec, mesh) == P(None, "data")


def test_mesh_axis_used_once(mesh):
    spec = PSpec((4864, 4864), ("ff", "vocab"))
    got = sh.spec_to_pspec(spec, mesh)
    used = [a for a in got if a is not None]
    assert len(set(map(str, used))) == len(used)


def test_fsdp_disabled(mesh):
    spec = PSpec((896, 14, 64), ("embed", "q_heads", "head_dim"))
    assert sh.spec_to_pspec(spec, mesh, fsdp=False) == P(None, None, None)


def test_batch_pspec_falls_back(mesh):
    # batch 1 (long_500k): nothing divides -> fully replicated
    assert sh.batch_pspec(mesh, 1, 2) == P(None, None)
    assert sh.batch_pspec(mesh, 256, 2) == P("data", None)


def test_multipod_fsdp_axes():
    mesh3 = sh.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    spec = PSpec((4608, 36864), ("embed", "ff"))
    got = sh.spec_to_pspec(spec, mesh3)
    assert got == P(("pod", "data"), "model")


def test_act_constrain_noop_without_mesh():
    x = jax.numpy.zeros((4, 8))
    y = act_sharding.constrain(x, [act_sharding.BATCH, act_sharding.MODEL])
    assert y is x   # identity outside a mesh context
