"""Optimizer, data pipeline, straggler monitor, transfer, fleet monitor."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import EnergyModel
from repro.core import transfer
from repro.core.fleet import EnergyMonitor
from repro.core.opcount import OpCounts
from repro.data.pipeline import DataConfig, host_batch
from repro.train import optimizer as opt_mod
from repro.train.elastic import StragglerMonitor, scale_batch


@pytest.fixture(scope="module")
def air_table():
    # store-backed (persistent TableStore): trained at most once per machine
    return EnergyModel.from_store("sim-v5e-air").table


@pytest.fixture(scope="module")
def liquid_table():
    return EnergyModel.from_store("sim-v5e-liquid").table


# ---- optimizer -------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    cfg = opt_mod.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                            weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt_mod.init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt_mod.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clipping_bounds_update():
    cfg = opt_mod.OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                            weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt_mod.init_opt_state(params, cfg)
    _, _, m = opt_mod.apply_updates(params, {"w": jnp.full(4, 1e6)}, state,
                                    cfg)
    assert float(m["grad_norm"]) > 1e5      # reported raw norm


def test_schedule_warmup_and_decay():
    cfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(opt_mod.schedule(jnp.asarray(s), cfg))
           for s in (0, 5, 10, 100)]
    assert lrs[1] < lrs[2]
    assert lrs[3] < lrs[2]
    assert abs(lrs[2] - 1.0) < 1e-6


def test_bf16_moments_option():
    cfg = opt_mod.OptConfig(mv_dtype="bfloat16", master_fp32=False)
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt_mod.init_opt_state(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    assert "master" not in state


# ---- data pipeline ----------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(seed=9, vocab=1000, seq_len=16, global_batch=8,
                     n_hosts=2)
    a0 = host_batch(cfg, step=5)
    a1 = host_batch(cfg, step=5)
    np.testing.assert_array_equal(a0["tokens"], a1["tokens"])
    b0 = host_batch(DataConfig(seed=9, vocab=1000, seq_len=16,
                               global_batch=8, n_hosts=2, host_id=1), 5)
    assert not np.array_equal(a0["tokens"], b0["tokens"])
    # targets are next-token shifted
    full = host_batch(cfg, 5)
    assert full["tokens"].shape == full["targets"].shape == (4, 16)


def test_data_streams_differ_by_step():
    cfg = DataConfig(seed=9, vocab=1000, seq_len=16, global_batch=4)
    assert not np.array_equal(host_batch(cfg, 1)["tokens"],
                              host_batch(cfg, 2)["tokens"])


# ---- elastic / straggler ------------------------------------------------------
def test_scale_batch():
    assert scale_batch(256, 256, 128) == 256
    assert scale_batch(256, 256, 96) == 192


def test_straggler_monitor_detects_persistent_slow():
    mon = StragglerMonitor(threshold=1.3, patience=2, window=4)
    ev = None
    for s in range(12):
        t = 1.0 if s < 8 else 2.0
        ev = mon.record(s, t) or ev
    assert ev is not None and ev.slow_factor > 1.3


def test_straggler_ignores_one_off_spike():
    mon = StragglerMonitor(threshold=1.3, patience=3, window=4)
    events = [mon.record(s, 1.0 if s != 5 else 3.0) for s in range(10)]
    assert not any(events)


# ---- transfer (Fig. 14) --------------------------------------------------------
def test_air_to_liquid_tables_strongly_linear(air_table, liquid_table):
    assert transfer.r2_between(air_table, liquid_table) > 0.95


def test_transfer_with_subset_keeps_structure(air_table, liquid_table):
    hybrid, fit = transfer.transfer_table(air_table, liquid_table, 0.5,
                                          seed=0)
    assert fit.r2 > 0.9
    assert set(hybrid.direct) >= set(air_table.direct) & set(liquid_table.direct)


def test_transfer_predicts_src_only_classes(air_table, liquid_table):
    # classes measured only on the donor must be affine-predicted into the
    # hybrid, not silently dropped (the point of Fig. 14)
    extra = dict(air_table.direct.items())
    extra["dot.fp8"] = 4.2e-13          # donor-only class (not in dst suite)
    donor = type(air_table)(system=air_table.system,
                            p_const=air_table.p_const,
                            p_static=air_table.p_static, direct=extra)
    assert "dot.fp8" not in liquid_table.direct
    hybrid, fit = transfer.transfer_table(donor, liquid_table, 0.5, seed=0)
    assert "dot.fp8" in hybrid.direct
    expected = max(fit.slope * extra["dot.fp8"] + fit.intercept, 0.0)
    assert hybrid.direct["dot.fp8"] == pytest.approx(expected)


# ---- fleet monitor (QMCPACK machinery) -------------------------------------------
def test_fleet_monitor_flags_spike(air_table):
    mon = EnergyMonitor(air_table, window=8, spike_ratio=1.5, min_share=0.01)
    base = OpCounts()
    base.add("dot.bf16", 1e9)
    base.add("exp.f32", 1e7)
    base.mxu_macs_total = base.mxu_macs_aligned = 1e9
    spike = OpCounts()
    spike.merge(base)
    spike.add("exp.f32", 2e8)      # the runaway-recompute class
    for step in range(20):
        mon.observe(step, spike if step == 15 else base, 0.01)
    assert any(a.cls == "exp.f32" and a.step == 15 for a in mon.anomalies)
