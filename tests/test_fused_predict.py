"""The jit-fused predictor hot path vs the plain batch: bitwise totals.

The fused kernel keeps every reduction that defines a ``Prediction`` total
in numpy (pairwise, same order as the plain path) and pushes only
elementwise IEEE work plus the bucket matmul into XLA — so ``total_j``,
``dynamic_j``, ``coverage`` and the per-class energy vector must match the
plain batch bit for bit, in both pred and direct modes, with and without
profiled memory counters.  ``by_bucket`` is the one deliberate exception:
the fused path gets it from one dgemm (different summation order), so it
is float-close, not bitwise.
"""
import numpy as np
import pytest

pytest.importorskip("jax", reason="the fused path needs jax")

from repro.core import coverage, isa
from repro.core.opcount import OpCounts
from repro.core.predict import _FUSED_MIN_JOBS, TablePredictor
from repro.core.table import EnergyTable

SEED = 11


def _table() -> EnergyTable:
    rng = np.random.default_rng(SEED)
    direct = {c.name: float(e) for c, e in
              zip(isa.OP_CLASSES,
                  rng.uniform(1e-12, 6e-11, len(isa.OP_CLASSES)))}
    t = EnergyTable(system="fused-test", p_const=40.0, p_static=55.0,
                    direct=direct)
    coverage.compute_bucket_means(t)
    return t


def _programs(n, with_counters=False):
    rng = np.random.default_rng(SEED + 1)
    names = [c.name for c in isa.OP_CLASSES]
    programs, durations, counters = [], [], []
    for _ in range(n):
        c = OpCounts()
        for cls in rng.choice(names, size=rng.integers(8, 28),
                              replace=False):
            c.add(str(cls), float(rng.uniform(1e3, 1e9)))
        c.boundary_read_bytes = float(rng.uniform(1e6, 1e10))
        c.boundary_write_bytes = float(rng.uniform(1e6, 1e10))
        c.fused_bytes = float(rng.uniform(1e6, 1e10))
        c.naive_bytes = c.boundary_bytes + c.fused_bytes
        programs.append(c)
        durations.append(float(rng.uniform(0.5, 30.0)))
        counters.append({"hbm_read_bytes": float(rng.uniform(1e6, 1e10)),
                         "hbm_write_bytes": float(rng.uniform(1e6, 1e10)),
                         "vmem_read_bytes": float(rng.uniform(1e5, 1e9)),
                         "vmem_write_bytes": float(rng.uniform(1e5, 1e9))}
                        if with_counters else None)
    return programs, durations, counters


@pytest.fixture(scope="module")
def predictors():
    plain = TablePredictor(_table())
    fused = TablePredictor(_table(), fused=True)
    plain.warm(), fused.warm()
    if not fused.enable_fused():
        pytest.skip("fused kernel unavailable on this host")
    return plain, fused


N = max(64, _FUSED_MIN_JOBS * 2)


@pytest.mark.parametrize("mode", ["pred", "direct"])
@pytest.mark.parametrize("with_counters", [False, True])
def test_fused_totals_bitwise_identical(predictors, mode, with_counters):
    plain, fused = predictors
    programs, durations, counters = _programs(N, with_counters)
    a = plain.predict_batch(programs, durations, counters, mode=mode)
    b = fused.predict_batch(programs, durations, counters, mode=mode)
    for pa, pb in zip(a, b):
        assert pa.total_j == pb.total_j
        assert pa.dynamic_j == pb.dynamic_j
        assert pa.coverage == pb.coverage
        assert pa.static_j == pb.static_j and pa.const_j == pb.const_j
        np.testing.assert_array_equal(pa.class_energy_vec,
                                      pb.class_energy_vec)


def test_fused_by_bucket_float_close_and_consistent(predictors):
    plain, fused = predictors
    programs, durations, _ = _programs(N)
    a = plain.predict_batch(programs, durations)
    b = fused.predict_batch(programs, durations)
    for pa, pb in zip(a, b):
        assert set(pb.by_bucket) == set(pa.by_bucket)
        for k, v in pa.by_bucket.items():
            assert pb.by_bucket[k] == pytest.approx(v, rel=1e-12)
        # buckets still recompose the total
        assert sum(pb.by_bucket.values()) == pytest.approx(pb.total_j)


def test_small_batches_silently_use_the_plain_path(predictors):
    plain, fused = predictors
    n = _FUSED_MIN_JOBS - 1
    programs, durations, _ = _programs(n)
    a = plain.predict_batch(programs, durations)
    b = fused.predict_batch(programs, durations)
    for pa, pb in zip(a, b):
        assert pa.total_j == pb.total_j
        assert pa.by_bucket == pb.by_bucket   # plain path: exact dict too
    # single predicts never pay the dispatch either
    pa = plain.predict(programs[0], durations[0])
    pb = fused.predict(programs[0], durations[0])
    assert pa.total_j == pb.total_j


def test_fused_flag_and_default_off():
    p = TablePredictor(_table())
    assert p._fused_requested is False
    assert p._ensure_fused() is None          # never built unless asked
