"""Chunked telemetry ingestion: the fast path must equal the reference.

Every stage of the streaming stack has a per-sample reference
implementation and a chunked ndarray fast path.  These tests feed identical
synthetic traces through both under randomized chunk boundaries (including
chunk size 1 and chunks straddling marker boundaries) and assert the
outputs are **bitwise identical** — ring contents and drop accounting,
integrated energy, per-window measured joules, attribution vectors, drift
verdicts, and the full ``StreamSummary``.  Plus the satellite coverage:
``SampleRing`` accounting for chunks larger than capacity, the
content-addressed profile cache, and ``TelemetryService.poll_all``.
"""
import math

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dependency (pip install .[dev])
    HAVE_HYPOTHESIS = False

from repro.api import EnergyModel
from repro.core.opcount import OpCounts
from repro.hw.device import SimDevice
from repro.hw.systems import SYSTEMS
from repro.telemetry import (FeedSampler, OnlineSteadyState, PowerSample,
                             SampleRing, StreamAligner, StreamingIntegrator,
                             TelemetryService, TraceReplaySampler,
                             contiguous_markers, iter_chunks)

SYSTEM = "sim-v5e-air"


def _counts() -> OpCounts:
    c = OpCounts()
    c.add("dot.bf16", 2e8)
    c.mxu_macs_total = c.mxu_macs_aligned = 2e8
    c.add("exp.f32", 1e6)
    c.add("add.f32", 5e6)
    c.boundary_read_bytes = 4e6
    c.boundary_write_bytes = 2e6
    c.naive_bytes = 8e6
    c.fused_bytes = 2e6
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


def _signal(n: int, seed: int = 0):
    ts = np.arange(n) * 0.1
    ps = (180.0 + 10.0 * np.sin(ts / 7.0)
          + np.random.default_rng(seed).normal(0.0, 1.5, n))
    return ts, ps


def _random_chunks(n: int, rng, max_chunk: int = 700):
    """Ragged chunk boundaries covering [0, n): includes size-1 chunks."""
    bounds = [0]
    while bounds[-1] < n:
        bounds.append(min(n, bounds[-1] + int(rng.integers(1, max_chunk))))
    return list(zip(bounds[:-1], bounds[1:]))


# ---------------------------------------------------------------------------
# SampleRing: bulk writes equal per-sample appends, accounting included.
# ---------------------------------------------------------------------------
def test_ring_extend_matches_append_randomized():
    rng = np.random.default_rng(1)
    ts, ps = _signal(20_000)
    us, cs = np.linspace(0, 1, ts.size), np.full(ts.size, 55.0)
    ref, fast = SampleRing(1000), SampleRing(1000)
    for i in range(ts.size):
        ref.append(PowerSample(ts[i], ps[i], us[i], cs[i]))
    for lo, hi in _random_chunks(ts.size, rng, max_chunk=3000):
        fast.extend(ts[lo:hi], ps[lo:hi], us[lo:hi], cs[lo:hi])
    assert fast.total == ref.total
    assert fast.dropped == ref.dropped
    a, b = ref.to_trace(), fast.to_trace()
    for f in ("times_s", "power_w", "util", "temp_c"):
        assert np.array_equal(getattr(a, f), getattr(b, f))
    assert fast.latest().power_w == ref.latest().power_w


def test_ring_chunk_larger_than_capacity_counts_invisible_drops():
    """Regression: a chunk bigger than the ring must count every sample it
    overwrote-before-visibility in ``dropped``, and ``_order`` must stay
    correct after the wrapping bulk write."""
    ref, fast = SampleRing(8), SampleRing(8)
    warm = np.arange(5, dtype=float)
    big = np.arange(5, 30, dtype=float)          # 25 > capacity
    for r, path in ((ref, "append"), (fast, "extend")):
        if path == "append":
            for v in np.concatenate([warm, big]):
                r.append(PowerSample(v, v * 2.0))
        else:
            r.extend(warm, warm * 2.0)
            r.extend(big, big * 2.0)
    assert fast.total == ref.total == 30
    assert fast.dropped == ref.dropped == 5 + 25 - 8
    assert len(fast) == 8
    t, p = fast.arrays()
    np.testing.assert_array_equal(t, np.arange(22, 30, dtype=float))
    np.testing.assert_array_equal(p, np.arange(22, 30, dtype=float) * 2.0)
    # a second wrapping write keeps the order invariant
    fast.extend(np.arange(30, 33, dtype=float), np.zeros(3))
    assert np.all(np.diff(fast.arrays()[0]) > 0)
    assert fast.dropped == ref.dropped + 3


def test_ring_extend_empty_and_default_fills():
    ring = SampleRing(16)
    assert ring.extend(np.empty(0), np.empty(0)) == 0
    ring.extend([1.0], [100.0])                  # util/temp default to nan
    assert math.isnan(ring.latest().util)
    assert ring.total == 1 and ring.dropped == 0


# ---------------------------------------------------------------------------
# Integrator / plateau: chunked == scalar, bitwise.
# ---------------------------------------------------------------------------
def test_integrator_chunked_bitwise_identical():
    rng = np.random.default_rng(2)
    ts, ps = _signal(20_000)
    ref, fast = StreamingIntegrator(), StreamingIntegrator()
    for i in range(ts.size):
        ref.add(ts[i], ps[i])
    for lo, hi in _random_chunks(ts.size, rng):
        fast.extend(ts[lo:hi], ps[lo:hi])
    assert fast.energy_j == ref.energy_j          # bitwise, not approx
    assert fast.n_samples == ref.n_samples
    assert fast.t_last == ref.t_last and fast.p_last == ref.p_last


def test_plateau_chunked_verdicts_and_start_match():
    rng = np.random.default_rng(3)
    # ramp -> plateau -> spike -> plateau: exercises start/reset transitions
    ps = np.concatenate([np.linspace(60, 150, 50),
                         150 + rng.normal(0, 1, 400),
                         [400.0] * 5,
                         150 + rng.normal(0, 1, 400)])
    ts = np.arange(ps.size) * 0.1
    ref, fast = OnlineSteadyState(), OnlineSteadyState()
    verdicts_ref = [ref.update(ts[i], ps[i]).steady for i in range(ts.size)]
    verdicts_fast = []
    state = None
    for lo, hi in _random_chunks(ts.size, rng, max_chunk=97):
        state, v = fast.update_chunk(ts[lo:hi], ps[lo:hi],
                                     with_verdicts=True)
        verdicts_fast.extend(v.tolist())
    assert verdicts_fast == verdicts_ref
    assert state.steady == verdicts_ref[-1]
    assert fast.start_s == ref.start_s or (
        math.isnan(fast.start_s) and math.isnan(ref.start_s))
    last = ref.update(ts[-1] + 0.1, 150.0)        # scalar after chunked state
    mixed = fast.update(ts[-1] + 0.1, 150.0)
    assert mixed.steady == last.steady


# ---------------------------------------------------------------------------
# Aligner: chunked alignment == per-sample alignment, bitwise.
# ---------------------------------------------------------------------------
def _assert_windows_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert (x.step, x.name) == (y.step, y.name)
        assert x.measured_j == y.measured_j       # bitwise
        assert x.covered_s == y.covered_s
        assert x.n_samples == y.n_samples
        assert x.clipped == y.clipped


@pytest.mark.parametrize("chunk", [1, 37, 100, 5000])
def test_aligner_chunked_bitwise_identical(chunk):
    ts, ps = _signal(5_000, seed=4)
    markers = contiguous_markers(ts[::100])       # chunks straddle windows
    ref, fast = StreamAligner(), StreamAligner()
    for m in markers:
        ref.add_marker(m)
        fast.add_marker(m)
    for i in range(ts.size):
        ref.add_sample(PowerSample(ts[i], ps[i]))
    for lo in range(0, ts.size, chunk):
        fast.add_samples(ts[lo:lo + chunk], ps[lo:lo + chunk])
    _assert_windows_equal(ref.close(), fast.close())


def test_aligner_late_markers_hold_chunks_back():
    ts, ps = _signal(1_000, seed=5)
    markers = contiguous_markers(ts[::250])
    ref, fast = StreamAligner(), StreamAligner()
    # samples first (held beyond the horizon), markers after
    for i in range(ts.size):
        ref.add_sample(PowerSample(ts[i], ps[i]))
    fast.add_samples(ts, ps)
    assert not fast.windows                       # everything held back
    for m in markers:
        ref.add_marker(m)
        fast.add_marker(m)
    _assert_windows_equal(ref.close(), fast.close())


def test_aligner_mixed_scalar_and_chunk_ingestion():
    ts, ps = _signal(600, seed=6)
    markers = contiguous_markers(ts[::150])
    ref, fast = StreamAligner(), StreamAligner()
    for m in markers:
        ref.add_marker(m)
        fast.add_marker(m)
    for i in range(ts.size):
        ref.add_sample(PowerSample(ts[i], ps[i]))
    fast.add_samples(ts[:200], ps[:200])
    for i in range(200, 400):                     # scalar in the middle
        fast.add_sample(PowerSample(ts[i], ps[i]))
    fast.add_samples(ts[400:], ps[400:])
    _assert_windows_equal(ref.close(), fast.close())


# ---------------------------------------------------------------------------
# Full pipeline: chunked StreamSession == per-sample StreamSession.
# ---------------------------------------------------------------------------
def _session_pair(chunk_size, steps=24, drift=False, name="chunkeq"):
    out = []
    for cs in (None, chunk_size):
        model = EnergyModel.from_store(SYSTEM)
        counts = _counts()
        if not drift:
            s = model.stream(counts, name=name, recalibrate=None,
                             chunk_size=cs)
            out.append((s, s.finish(steps=steps)))
            continue
        shakedown = model.stream(counts, name=name, chunk_size=cs)
        shakedown.finish(steps=steps)
        cfg = SYSTEMS[SYSTEM]
        model._device = SimDevice(cfg.chip, cfg.cooling, cfg.seed,
                                  name=cfg.name, coeff_scale=1.5)
        s = model.stream(counts, name=name, chunk_size=cs,
                         attributor=shakedown.attributor)
        out.append((s, s.finish(steps=40)))
    return out


def _assert_summaries_bitwise(a, b):
    assert a.measured_total_j == b.measured_total_j
    assert a.startup_j == b.startup_j
    assert a.predicted_total_j == b.predicted_total_j
    assert a.mape_pct == b.mape_pct
    assert a.n_samples == b.n_samples
    assert a.dropped_samples == b.dropped_samples
    assert a.steps == b.steps and a.duration_s == b.duration_s
    assert a.recalibrations == b.recalibrations
    assert (a.drift.drifting, a.drift.ratio, a.drift.n) == \
        (b.drift.drifting, b.drift.ratio, b.drift.n)


@pytest.mark.parametrize("chunk_size", [1, 37, 4096])
def test_session_chunked_summary_bitwise_identical(chunk_size):
    (ref, ref_sum), (fast, fast_sum) = _session_pair(chunk_size)
    _assert_summaries_bitwise(ref_sum, fast_sum)
    _assert_windows_equal(ref.windows, fast.windows)
    # per-window measured_j tiles the identical total on both paths
    assert sum(w.measured_j for w in fast.windows) == pytest.approx(
        fast_sum.measured_total_j, rel=1e-9)
    for x, y in zip(ref.attributions, fast.attributions):
        assert x.predicted_j == y.predicted_j
        assert x.measured_j == y.measured_j
        assert x.measured_dyn_j == y.measured_dyn_j
        assert np.array_equal(x.measured_class_vec, y.measured_class_vec)
    assert fast.plateau.start_s == ref.plateau.start_s or (
        math.isnan(fast.plateau.start_s)
        and math.isnan(ref.plateau.start_s))


def test_session_chunked_drift_repair_bitwise_identical():
    (_, ref_sum), (_, fast_sum) = _session_pair(256, drift=True)
    assert ref_sum.recalibrations, "drift scenario never repaired"
    _assert_summaries_bitwise(ref_sum, fast_sum)


# ---------------------------------------------------------------------------
# Samplers & service.
# ---------------------------------------------------------------------------
def test_trace_replay_chunks_are_zero_copy_slices():
    model = EnergyModel.from_store(SYSTEM)
    rec = model.measure(_counts(), target_seconds=5.0, name="zc")
    sampler = TraceReplaySampler(rec.trace)
    t_all = np.concatenate([c[0] for c in sampler.chunks(64)])
    np.testing.assert_array_equal(t_all, rec.trace.times_s)
    first = next(sampler.chunks(64))[0]
    assert first.base is rec.trace.times_s        # a view, not a copy


def test_iter_chunks_falls_back_for_per_sample_sources():
    feed = FeedSampler([(0.0, 100.0), (1.0, 110.0, 0.5), (2.0, 120.0)])
    chunks = list(iter_chunks(feed, 2))
    assert [c[0].size for c in chunks] == [2, 1]
    assert chunks[0][1][1] == 110.0 and chunks[0][2][1] == 0.5

    class Bare:                                   # no chunks() method
        def __iter__(self):
            return iter([PowerSample(0.0, 90.0), PowerSample(1.0, 91.0)])

    (t, p, u, c), = list(iter_chunks(Bare(), 8))
    np.testing.assert_array_equal(p, [90.0, 91.0])
    assert np.isnan(u).all()


def test_service_poll_all_drains_the_fleet():
    service = TelemetryService()
    model = EnergyModel.from_store(SYSTEM)
    s1 = model.stream(_counts(), name="a", recalibrate=None, service=service,
                      chunk_size=64)
    s2 = model.stream(_counts(), name="b", recalibrate=None, service=service,
                      chunk_size=64)
    assert service.poll_all() == 0                # nothing started yet
    s1.start(steps=6)
    s2.start(steps=6)
    total = 0
    passes = 0
    while True:
        got = service.poll_all(max_chunks=2)
        if not got:
            break
        total += got
        passes += 1
    assert s1.summary is not None and s2.summary is not None
    assert total == s1.summary.n_samples + s2.summary.n_samples
    assert passes > 1                             # genuinely incremental
    snap = service.snapshot()
    assert snap["fleet"]["n_sessions"] == 2
    assert snap["fleet"]["samples"] == total
    assert service.finish_all().keys() == service.sessions().keys()


def test_session_step_after_start_rejected():
    model = EnergyModel.from_store(SYSTEM)
    s = model.stream(_counts(), name="lock", recalibrate=None, chunk_size=32)
    s.step(0)
    s.start(steps=4)
    with pytest.raises(RuntimeError):
        s.step(1)
    s.finish()
    assert s.summary.steps == 4


def test_finish_fewer_steps_than_registered_reports_marker_count():
    model = EnergyModel.from_store(SYSTEM)
    s = model.stream(_counts(), name="trunc", recalibrate=None)
    for i in range(10):
        s.step(i)
    summary = s.finish(steps=5)                   # only 5 marker windows
    assert summary.steps == 5
    assert len(s.attributions) == 5


def test_monitor_telemetry_chunk_requires_live():
    model = EnergyModel.from_store(SYSTEM)
    with pytest.raises(ValueError):
        model.monitor(step_counts=_counts(), telemetry_chunk=64)


# ---------------------------------------------------------------------------
# Satellite: content-addressed profile cache.
# ---------------------------------------------------------------------------
def test_profile_cache_hits_on_identical_programs():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    model = EnergyModel.from_store(SYSTEM)

    def fn(x, w):
        return jnp.sum(jax.nn.gelu(x @ w))

    args = (jax.ShapeDtypeStruct((128, 64), jnp.bfloat16),
            jax.ShapeDtypeStruct((64, 32), jnp.bfloat16))
    p1 = model.profile(fn, *args)
    p2 = model.profile(fn, *args)
    stats = model.stats()["profile_cache"]
    assert stats == {"hits": 1, "misses": 1, "entries": 1, "maxsize": 256}
    assert p1.counts.as_dict() == p2.counts.as_dict()
    # handed-out counts are copies: mutation cannot poison the cache
    p2.counts.boundary_read_bytes += 1e9
    p3 = model.profile(fn, *args)
    assert p3.counts.as_dict() == p1.counts.as_dict()

    hlo = "HloModule m\nENTRY e { ROOT r = f32[4,4] parameter(0) }\n"
    h1 = model.profile_hlo(hlo)
    h2 = model.profile_hlo(hlo)
    assert h1.counts.as_dict() == h2.counts.as_dict()
    stats = model.stats()["profile_cache"]
    assert stats["hits"] == 3 and stats["misses"] == 2

    # different program -> different digest -> miss
    model.profile_hlo(hlo.replace("4,4", "8,8"))
    assert model.stats()["profile_cache"]["misses"] == 3
    assert model.stats()["system"] == SYSTEM


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.data())
    def test_property_chunked_equals_scalar(data):
        """Any trace, any chunking: chunked ingestion == the reference."""
        n = data.draw(st.integers(min_value=2, max_value=200), label="n")
        power = np.asarray(data.draw(
            st.lists(st.floats(min_value=0.0, max_value=1000.0,
                               allow_nan=False, allow_infinity=False),
                     min_size=n, max_size=n), label="power"))
        dts = np.asarray(data.draw(
            st.lists(st.floats(min_value=1e-3, max_value=2.0),
                     min_size=n, max_size=n), label="dts"))
        ts = np.cumsum(dts)
        every = data.draw(st.integers(min_value=1, max_value=max(n // 2, 1)),
                          label="marker_every")
        bounds = ts[::every]
        markers = (contiguous_markers(bounds) if bounds.size >= 2 else [])

        ref_i, fast_i = StreamingIntegrator(), StreamingIntegrator()
        ref_p, fast_p = OnlineSteadyState(), OnlineSteadyState()
        ref_a, fast_a = StreamAligner(), StreamAligner()
        ref_r, fast_r = SampleRing(max(n // 3, 2)), SampleRing(max(n // 3, 2))
        for m in markers:
            ref_a.add_marker(m)
            fast_a.add_marker(m)
        verdicts_ref = []
        for i in range(n):
            ref_i.add(ts[i], power[i])
            verdicts_ref.append(ref_p.update(ts[i], power[i]).steady)
            ref_a.add_sample(PowerSample(ts[i], power[i]))
            ref_r.append(PowerSample(ts[i], power[i]))
        verdicts_fast = []
        lo = 0
        while lo < n:
            hi = min(n, lo + data.draw(
                st.integers(min_value=1, max_value=n), label="chunk"))
            fast_i.extend(ts[lo:hi], power[lo:hi])
            _, v = fast_p.update_chunk(ts[lo:hi], power[lo:hi],
                                       with_verdicts=True)
            verdicts_fast.extend(v.tolist())
            fast_a.add_samples(ts[lo:hi], power[lo:hi])
            fast_r.extend(ts[lo:hi], power[lo:hi])
            lo = hi
        assert fast_i.energy_j == ref_i.energy_j
        assert verdicts_fast == verdicts_ref
        _assert_windows_equal(ref_a.close(), fast_a.close())
        assert fast_r.dropped == ref_r.dropped
        assert np.array_equal(fast_r.arrays()[0], ref_r.arrays()[0])
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(optional dev dependency, pip install .[dev])")
    def test_property_chunked_equals_scalar():
        pass


def test_profile_cache_lru_bounded():
    from repro.api import ProfileCache
    cache = ProfileCache(maxsize=2)
    mk = OpCounts
    cache.get_or_count(("k", 1), mk)
    cache.get_or_count(("k", 2), mk)
    cache.get_or_count(("k", 1), mk)              # refresh 1
    cache.get_or_count(("k", 3), mk)              # evicts 2
    assert len(cache) == 2
    cache.get_or_count(("k", 2), mk)
    assert cache.stats()["misses"] == 4 and cache.stats()["hits"] == 1
