"""Streaming telemetry: ingestion, MTSM alignment, drift detection/repair.

Acceptance criteria covered here:
  (a) streaming integration matches offline ``integrate_trace`` to <0.1%;
  (b) aligned per-step measured energy sums to the run total;
  (c) an injected table-drift scenario (hidden-model coefficients scaled)
      is flagged and corrected, restoring error to the pre-drift band.
Plus the satellite coverage: ``total_energy``'s short-run fallback and
marker↔trace alignment edge cases.
"""
import json
import math

import numpy as np
import pytest

from repro.api import EnergyModel
from repro.core import measure
from repro.core.opcount import OpCounts
from repro.hw.device import Program, RunRecord, SensorTrace, SimDevice
from repro.hw.systems import SYSTEMS
from repro.telemetry import (FeedSampler, Marker, OnlineSteadyState,
                             PowerSample, SampleRing, StreamAligner,
                             StreamingIntegrator, TelemetryService,
                             TraceReplaySampler, align_trace,
                             contiguous_markers, rolling_std)


def _counts() -> OpCounts:
    c = OpCounts()
    c.add("dot.bf16", 2e8)
    c.mxu_macs_total = c.mxu_macs_aligned = 2e8
    c.add("exp.f32", 1e6)
    c.add("add.f32", 5e6)
    c.boundary_read_bytes = 4e6
    c.boundary_write_bytes = 2e6
    c.naive_bytes = 8e6
    c.fused_bytes = 2e6
    c.max_buffer_bytes = 4e6
    c.dispatch_count = 3
    return c


@pytest.fixture(scope="module")
def model():
    return EnergyModel.from_store("sim-v5e-air")


@pytest.fixture(scope="module")
def run_record(model):
    return model.measure(_counts(), target_seconds=20.0, name="telemetry")


def _trace(power, hz=10.0):
    n = len(power)
    t = np.arange(n) / hz
    return SensorTrace(t, np.asarray(power, float), np.ones(n),
                       np.full(n, 50.0))


# ---------------------------------------------------------------------------
# (a) streaming integration == offline integration.
# ---------------------------------------------------------------------------
def test_streaming_integration_matches_offline(run_record):
    trace = run_record.trace
    offline = measure.integrate_trace(trace)

    per_sample = StreamingIntegrator()
    for s in TraceReplaySampler(trace):
        per_sample.add(s.t_s, s.power_w)
    assert per_sample.energy_j == pytest.approx(offline, rel=1e-3)
    # the acceptance bound is 0.1%; the shared implementation is far tighter
    assert abs(per_sample.energy_j - offline) <= 1e-9 * max(offline, 1.0)

    chunked = StreamingIntegrator()
    t, p = trace.times_s, trace.power_w
    for lo in range(0, len(t), 37):          # ragged chunk boundaries
        chunked.extend(t[lo:lo + 37], p[lo:lo + 37])
    assert chunked.energy_j == pytest.approx(offline, rel=1e-9)
    assert chunked.n_samples == len(t)


def test_rolling_std_matches_naive():
    rng = np.random.default_rng(7)
    p = rng.normal(150.0, 8.0, 400)
    w = 23
    got = rolling_std(p, w)
    want = np.array([np.std(p[i:i + w]) for i in range(len(p) - w + 1)])
    np.testing.assert_allclose(got, want, atol=1e-8)
    assert rolling_std(p[:5], 10).size == 0


def test_online_plateau_agrees_with_offline_detector():
    power = np.concatenate([np.linspace(60, 150, 50),
                            150 + np.random.default_rng(0).normal(0, 1, 550)])
    trace = _trace(power)
    ss = measure.detect_steady_state(trace)
    online = OnlineSteadyState()
    state = None
    for i in range(len(power)):
        state = online.update(trace.times_s[i], trace.power_w[i])
    assert state.steady
    assert state.mean_w == pytest.approx(ss.power_w, rel=0.05)


# ---------------------------------------------------------------------------
# (b) aligned per-step energies tile the run exactly.
# ---------------------------------------------------------------------------
def test_aligned_windows_sum_to_run_total(model):
    session = model.stream(_counts(), name="telemetry", recalibrate=None)
    summary = session.finish(steps=24)
    total_windows = sum(w.measured_j for w in session.windows)
    assert total_windows == pytest.approx(summary.measured_total_j, rel=1e-9)
    # and the streamed total matches the offline integral of the same trace
    assert summary.measured_total_j == pytest.approx(
        measure.integrate_trace(session.record.trace), rel=1e-9)
    step_sum = sum(a.measured_j for a in session.attributions)
    assert step_sum == pytest.approx(
        summary.measured_total_j - summary.startup_j, rel=1e-9)
    assert len(session.attributions) == 24
    assert all(w.n_samples > 0 for w in session.windows)


def test_alignment_edge_cases():
    # constant 100 W sampled at 1 Hz over t = 0..9
    trace = _trace(np.full(10, 100.0), hz=1.0)
    markers = [
        Marker(0, "before", -5.0, -1.0),       # entirely before the trace
        Marker(1, "straddle_start", -1.0, 1.0),
        Marker(2, "between_samples", 2.25, 2.75),
        Marker(3, "straddle_end", 8.5, 12.0),  # runs past the last sample
    ]
    wins = {w.name: w for w in align_trace(trace, markers)}
    assert wins["before"].measured_j == 0.0
    assert wins["before"].clipped
    assert wins["straddle_start"].measured_j == pytest.approx(100.0)
    assert wins["straddle_start"].clipped            # 1s of 2s covered
    assert wins["between_samples"].measured_j == pytest.approx(50.0)
    assert not wins["between_samples"].clipped
    assert wins["straddle_end"].measured_j == pytest.approx(50.0)
    assert wins["straddle_end"].clipped


def test_alignment_interpolates_inside_a_segment():
    # p(t) = 10 t: energy over [0.25, 0.75] is 5*(0.75^2 - 0.25^2) = 2.5
    trace = SensorTrace(np.array([0.0, 1.0]), np.array([0.0, 10.0]),
                        np.ones(2), np.full(2, 50.0))
    (win,) = align_trace(trace, [Marker(0, "w", 0.25, 0.75)])
    assert win.measured_j == pytest.approx(2.5)


def test_late_markers_receive_held_samples():
    trace = _trace(np.full(10, 100.0), hz=1.0)
    eager = StreamAligner()
    eager.add_marker(Marker(0, "w", 2.0, 6.0))
    for s in TraceReplaySampler(trace):
        eager.add_sample(s)
    lazy = StreamAligner()
    for s in TraceReplaySampler(trace):
        lazy.add_sample(s)                    # samples first: held back
    lazy.add_marker(Marker(0, "w", 2.0, 6.0))
    assert lazy.close()[0].measured_j == pytest.approx(
        eager.close()[0].measured_j)
    assert lazy.windows[0].measured_j == pytest.approx(400.0)


def test_overlapping_markers_rejected():
    a = StreamAligner()
    a.add_marker(Marker(0, "x", 0.0, 2.0))
    with pytest.raises(ValueError):
        a.add_marker(Marker(1, "y", 1.0, 3.0))
    with pytest.raises(ValueError):
        Marker(2, "z", 5.0, 4.0)


def test_contiguous_markers_tile():
    ms = contiguous_markers([0.0, 1.5, 3.0, 7.0], first_step=5)
    assert [m.step for m in ms] == [5, 6, 7]
    assert ms[0].t_end_s == ms[1].t_start_s
    with pytest.raises(ValueError):
        contiguous_markers([1.0])
    with pytest.raises(ValueError):
        contiguous_markers([2.0, 1.0])


# ---------------------------------------------------------------------------
# (c) injected drift is flagged and repaired.
# ---------------------------------------------------------------------------
def test_drift_flagged_and_recalibrated():
    model = EnergyModel.from_store("sim-v5e-air")
    counts = _counts()

    # phase 1: healthy silicon — anchors the workload's baseline ratio
    s1 = model.stream(counts, name="telemetry")
    m1 = s1.finish(steps=24)
    assert not m1.recalibrations
    assert not m1.drift.drifting
    assert math.isfinite(m1.drift.baseline)
    band = max(abs(a.error_pct) for a in s1.attributions)

    # phase 2: same table, drifted part — hidden coefficients 50% hot
    cfg = SYSTEMS["sim-v5e-air"]
    model._device = SimDevice(cfg.chip, cfg.cooling, cfg.seed,
                              name=cfg.name, coeff_scale=1.5)
    s2 = model.stream(counts, name="telemetry", attributor=s1.attributor)
    m2 = s2.finish(steps=40)
    assert m2.recalibrations, "drift was never flagged/repaired"
    total_scale = float(np.prod(m2.recalibrations))
    # tracks the injected 1.5x (plus the in-session thermal-leakage ramp)
    assert 1.2 < total_scale < 2.1
    assert model.table.meta["recalibrated_scale"] == pytest.approx(
        total_scale)

    # post-repair error returns to the pre-drift band
    post = [abs(a.error_pct) for a in s2.attributions[-8:]]
    assert float(np.mean(post)) <= band + 2.0


def test_recalibration_custom_trigger_and_reset():
    from repro.telemetry.attrib import DriftDetector, OnlineAttributor
    from repro.core.predict import TablePredictor
    model = EnergyModel.from_store("sim-v5e-air")
    fired = []
    att = OnlineAttributor(TablePredictor(model.table),
                           recalibrate=lambda a, st: fired.append(st.ratio),
                           detector=DriftDetector(rel_tol=0.05,
                                                  baseline_windows=2,
                                                  patience=2))
    win = Marker(0, "w", 0.0, 1.0)
    aligned = align_trace(_trace(np.full(20, 200.0), hz=10.0), [win])[0]
    for _ in range(4):
        att.attribute(aligned, _counts())
    # identical windows: ratio constant == baseline -> no drift
    assert not fired
    hot = align_trace(_trace(np.full(20, 400.0), hz=10.0), [win])[0]
    for _ in range(12):
        att.attribute(hot, _counts())
    assert fired, "custom trigger never fired"


# ---------------------------------------------------------------------------
# Satellite: total_energy short-run fallback.
# ---------------------------------------------------------------------------
def _record_from(trace: SensorTrace) -> RunRecord:
    return RunRecord(name="r", duration_s=float(trace.times_s[-1]), iters=1,
                     trace=trace, energy_counter_j=123.0, counters={})


def test_total_energy_short_run_falls_back_to_trapezoid():
    # a ramp that never settles: the detected plateau is the trailing
    # window, so steady span <= half the run -> trapezoid integration
    power = np.linspace(50.0, 300.0, 120)
    trace = _trace(power)
    rec = _record_from(trace)
    ss = measure.detect_steady_state(trace)
    assert rec.duration_s - ss.start_s <= 0.5 * rec.duration_s
    assert measure.total_energy(rec) == pytest.approx(
        measure.integrate_trace(trace))


def test_total_energy_steady_run_uses_plateau_formulation():
    rng = np.random.default_rng(3)
    power = np.concatenate([np.linspace(40, 200, 20),
                            200 + rng.normal(0, 1, 580)])
    trace = _trace(power)
    rec = _record_from(trace)
    ss = measure.detect_steady_state(trace)
    assert rec.duration_s - ss.start_s > 0.5 * rec.duration_s
    total = measure.total_energy(rec)
    assert total != pytest.approx(measure.integrate_trace(trace), rel=1e-12)
    assert total == pytest.approx(measure.integrate_trace(trace), rel=0.02)
    assert measure.total_energy(rec, use_counter=True) == 123.0


# ---------------------------------------------------------------------------
# Plumbing: ring buffer, samplers, monitor wiring, service snapshot.
# ---------------------------------------------------------------------------
def test_sample_ring_overwrites_oldest():
    ring = SampleRing(capacity=8)
    for i in range(12):
        ring.append(PowerSample(float(i), 100.0 + i))
    assert len(ring) == 8
    assert ring.total == 12
    assert ring.dropped == 4
    t, p = ring.arrays()
    np.testing.assert_allclose(t, np.arange(4, 12, dtype=float))
    assert ring.latest().power_w == pytest.approx(111.0)
    assert ring.to_trace().duration() == pytest.approx(7.0)


def test_feed_sampler_tuples_and_callable():
    samples = list(FeedSampler([(0.0, 100.0), (1.0, 110.0, 0.5)]))
    assert [s.power_w for s in samples] == [100.0, 110.0]
    assert samples[1].util == 0.5
    feed = iter([(0.0, 90.0), None, (9.0, 9.0)])
    polled = list(FeedSampler(lambda: next(feed)))
    assert len(polled) == 1                   # None terminates the poll loop


def test_monitor_live_records_measured_energy(model):
    mon = model.monitor(live=True, step_counts=_counts(), window=4)
    assert mon.live is not None
    for i in range(10):
        mon.live.step(i, duration_s=0.01, work_units=64.0)
    summary = mon.live.finish()
    assert summary.steps == 10
    assert len(mon.records) == 10
    assert all(r.measured_j is not None and r.measured_j > 0
               for r in mon.records)
    assert all(r.error_pct is not None for r in mon.records)


def test_monitor_step_counts_default_and_validation(model):
    mon = model.monitor(step_counts=_counts())
    rec = mon.observe(0, duration_s=0.5)      # counts default in
    assert rec.prediction.total_j > 0
    bare = model.monitor()
    with pytest.raises(ValueError):
        bare.observe(0, duration_s=0.5)
    bare.set_step_counts(_counts())
    assert bare.observe(0, duration_s=0.5).prediction.total_j > 0
    with pytest.raises(ValueError):
        model.monitor(live=True)              # live needs a source


# ---------------------------------------------------------------------------
# Kernel microscopy: per-launch windows tile each step's energy bitwise.
# ---------------------------------------------------------------------------
def test_kernel_scope_windows_tile_steps_bitwise(model):
    session = model.stream(_counts(), name="microscopy", recalibrate=None)
    with session.kernel_scope("flash", config=(512, 512),
                              counts=_counts().scaled(0.4)):
        pass
    with session.kernel_scope("decode", variant="ref",
                              counts=_counts().scaled(0.2)):
        pass
    for i in range(6):
        session.step(i)
    summary = session.finish()

    steps = [w for w in session.windows if w.step >= 0]
    assert len(steps) == 6 and all(w.children for w in steps)
    for w in steps:
        # the headline guarantee: exact float equality, not approx
        assert sum(c.measured_j for c in w.children) == w.measured_j
        assert w.children[0].t_start_s == w.t_start_s
        assert w.children[-1].t_end_s == w.t_end_s
        for a, b in zip(w.children, w.children[1:]):
            assert a.t_end_s == b.t_start_s          # shared boundary
        names = [c.name for c in w.children]
        assert "flash" in names and "decode" in names
    # and the step windows still tile the run total, as without scopes
    assert sum(w.measured_j for w in session.windows) == pytest.approx(
        summary.measured_total_j, rel=1e-9)

    rep = session.kernel_report()
    assert rep["flash"]["variant"] == "pallas"
    assert rep["flash"]["config"] == [512, 512]
    assert rep["decode"]["variant"] == "ref"
    flash_sum = sum(c.measured_j for w in steps for c in w.children
                    if c.name == "flash")
    assert rep["flash"]["energy_j"] == flash_sum
    assert rep["flash"]["windows"] == 6
    assert rep["flash"]["j_per_launch"] == pytest.approx(
        flash_sum / rep["flash"]["launches"])
    # report energies (incl. the unattributed filler) sum to the step total
    assert sum(d["energy_j"] for d in rep.values()) == pytest.approx(
        sum(w.measured_j for w in steps), rel=1e-12)


def test_kernel_scope_lifecycle_and_overlap_rejected(model):
    session = model.stream(_counts(), name="scopes", recalibrate=None)
    with pytest.raises(ValueError, match="not overlap"):
        with session.kernel_scope("outer"):
            with session.kernel_scope("inner"):
                pass
    session.step(0)
    session.start(steps=1)
    with pytest.raises(RuntimeError, match="started"):
        with session.kernel_scope("late"):
            pass
    session.finish()
    with pytest.raises(RuntimeError, match="finished"):
        with session.kernel_scope("done"):
            pass


def test_subdivide_marker_gaps_tail_and_zero_duration():
    from types import SimpleNamespace as NS
    from repro.telemetry.align import subdivide_marker
    parent = Marker(3, "step", 10.0, 11.0)
    spans = [NS(name="a", variant="pallas", config=(128,),
                frac_start=0.1, frac_end=0.4),
             NS(name="z", variant="pallas", config=(),
                frac_start=0.4, frac_end=0.4),       # zero-duration launch
             NS(name="b", variant="ref", config=(),
                frac_start=0.7, frac_end=1.0)]
    kids = subdivide_marker(parent, spans)
    assert [k.name for k in kids] == ["__unattributed__", "a", "z",
                                      "__unattributed__", "b"]
    assert kids[0].t_start_s == parent.t_start_s
    assert kids[-1].t_end_s == parent.t_end_s
    for x, y in zip(kids, kids[1:]):
        assert x.t_end_s == y.t_start_s              # bit-for-bit chained
    assert kids[2].duration_s == 0.0
    assert kids[1].variant == "pallas" and kids[1].config == (128,)
    # an empty span list yields the pure-filler subdivision
    (filler,) = subdivide_marker(parent, [])
    assert filler.name == "__unattributed__"
    assert (filler.t_start_s, filler.t_end_s) == (10.0, 11.0)


def test_zero_duration_kernel_window_gets_zero_energy():
    parent = Marker(0, "step", 0.0, 4.0)
    kids = [Marker(0, "k0", 0.0, 2.0), Marker(0, "kz", 2.0, 2.0),
            Marker(0, "k1", 2.0, 4.0)]
    a = StreamAligner()
    a.add_marker(parent, kids)
    for s in TraceReplaySampler(_trace(np.full(5, 100.0), hz=1.0)):
        a.add_sample(s)
    (w,) = a.close()
    z = {c.name: c for c in w.children}["kz"]
    assert z.measured_j == 0.0 and z.n_samples == 0
    assert sum(c.measured_j for c in w.children) == w.measured_j
    assert w.measured_j == pytest.approx(400.0)


def test_nontiling_children_rejected():
    a = StreamAligner()
    parent = Marker(0, "step", 0.0, 4.0)
    with pytest.raises(ValueError, match="children given but empty"):
        a.add_marker(parent, [])
    with pytest.raises(ValueError, match="exactly tile"):
        a.add_marker(parent, [Marker(0, "gap", 0.5, 4.0)])
    with pytest.raises(ValueError, match="exactly tile"):
        a.add_marker(parent, [Marker(0, "short", 0.0, 3.5)])
    with pytest.raises(ValueError, match="exactly tile"):
        a.add_marker(parent, [Marker(0, "x", 0.0, 2.0),
                              Marker(0, "y", 1.5, 4.0)])


def test_kernel_tiling_survives_chunk_boundaries():
    """Chunked ingestion that splits mid-child matches the scalar path
    bitwise, child by child, for every chunking."""
    parent = Marker(0, "step", 0.0, 8.0)
    kids = [Marker(0, "k0", 0.0, 3.3), Marker(0, "k1", 3.3, 5.7),
            Marker(0, "k2", 5.7, 8.0)]
    power = 150.0 + 30.0 * np.sin(np.arange(90) / 7.0)
    trace = _trace(power, hz=10.0)            # t = 0 .. 8.9
    ref = StreamAligner()
    ref.add_marker(parent, list(kids))
    for s in TraceReplaySampler(trace):
        ref.add_sample(s)
    (ref_win,) = ref.close()
    assert sum(c.measured_j for c in ref_win.children) == ref_win.measured_j

    t, p = trace.times_s, trace.power_w
    for size in (1, 7, 33, 90):               # 7/33 straddle child edges
        al = StreamAligner()
        al.add_marker(parent, list(kids))
        for lo in range(0, len(t), size):
            al.add_samples(t[lo:lo + size], p[lo:lo + size])
        (win,) = al.close()
        assert win.measured_j == ref_win.measured_j
        for got, want in zip(win.children, ref_win.children):
            assert got.measured_j == want.measured_j
        assert sum(c.measured_j for c in win.children) == win.measured_j


def test_service_snapshot_round_trips(model):
    service = TelemetryService()
    session = model.stream(_counts(), name="svc", service=service,
                           recalibrate=None)
    session.finish(steps=6)
    snap = json.loads(service.to_json())
    assert snap["fleet"]["n_sessions"] == 1
    (sess,) = snap["sessions"].values()
    assert sess["finished"] and sess["windows"] == 7   # 6 steps + startup
    assert sess["measured_j"] > 0
    with pytest.raises(KeyError):
        service.register(session, key="sim-v5e-air/svc")
