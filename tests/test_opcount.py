"""Unit tests for the jaxpr op counter (the profiler) and the array-backed
``OpCounts`` currency (``core.counting``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import counting, isa, opcount
from repro.core.counting import OpCounts


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_scan_multiplies_counts():
    def fn(x):
        def body(c, _):
            return c + 1.0, ()
        c, _ = jax.lax.scan(body, x, None, length=37)
        return c
    c = opcount.count_fn(fn, _sds((8, 16)))
    assert c.units["add.f32"] == 37 * 8 * 16
    assert c.units["ctl.loop"] == 37


def test_dot_macs_and_alignment():
    def fn(a, b):
        return a @ b
    c = opcount.count_fn(fn, _sds((256, 512)), _sds((512, 128)))
    assert c.units["dot.f32"] == 256 * 512 * 128
    assert c.flops == 2 * 256 * 512 * 128
    assert c.mxu_macs_aligned == c.mxu_macs_total   # all dims %128 == 0

    c2 = opcount.count_fn(fn, _sds((100, 512)), _sds((512, 128)))
    assert c2.mxu_macs_aligned == 0                 # 100 not aligned


def test_batched_dot():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c = opcount.count_fn(fn, _sds((4, 32, 64)), _sds((4, 64, 16)))
    assert c.units["dot.f32"] == 4 * 32 * 64 * 16


def test_arch_gen_remaps_dot_forms():
    def small(a, b):
        return a @ b
    c0 = opcount.count_fn(small, _sds((16, 64)), _sds((64, 32)))
    assert "dot.f32" in c0.units
    c1 = opcount.count_fn(small, _sds((16, 64)), _sds((64, 32)), isa_gen=1)
    assert "dot_small.f32" in c1.units and "dot.f32" not in c1.units

    def batched(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c2 = opcount.count_fn(batched, _sds((4, 256, 256)), _sds((4, 256, 256)),
                          isa_gen=2)
    assert "dot_group.f32" in c2.units


def test_convert_classes():
    def fn(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    c = opcount.count_fn(fn, _sds((128, 128)))
    assert c.units["convert.f32.bf16"] == 128 * 128
    assert c.units["convert.bf16.f32"] == 128 * 128


def test_elementwise_dtype_grouping():
    def fn(x):
        return jnp.exp(x) + jnp.tanh(x)
    c = opcount.count_fn(fn, _sds((64, 64), jnp.bfloat16))
    assert c.units["exp.bf16"] == 64 * 64
    assert c.units["tanh.bf16"] == 64 * 64
    assert c.units["add.bf16"] == 64 * 64


def test_gather_io_only_touched_rows():
    def fn(table, idx):
        return table[idx]
    c = opcount.count_fn(fn, _sds((100000, 64)), _sds((32,), jnp.int32))
    # traffic ~ gathered rows (+ index bookkeeping), not the whole table
    assert c.naive_bytes < 3 * (32 * 64 * 4)
    assert c.units["gather"] == 32 * 64


def test_fusion_boundary_vs_fused():
    def chain(x):
        for _ in range(10):
            x = x * 1.5
        return x
    c = opcount.count_fn(chain, _sds((128, 128)))
    # 10-op chain: only first read + last write are boundary
    assert c.fused_bytes > 4 * c.boundary_bytes


def test_collective_wire_bytes_math():
    b = 1024.0
    assert opcount._COLLECTIVES["psum"][1](b, 8) == 2 * b * 7 / 8
    assert opcount._COLLECTIVES["all_gather"][1](b, 8) == b * 7
    assert opcount._COLLECTIVES["ppermute"][1](b, 8) == b


def test_cond_counts_worst_branch():
    def fn(x, p):
        return jax.lax.cond(p, lambda v: v @ v, lambda v: v + 1.0, x)
    c = opcount.count_fn(fn, _sds((64, 64)), _sds((), jnp.bool_))
    assert c.units.get("dot.f32", 0) == 64 * 64 * 64
    assert c.units["ctl.cond"] == 1


def test_unknown_class_reaches_bucketing():
    # sub.int has no table entry but must bucket as integer-lane work
    assert "sub.int" not in isa.CLASS_BY_NAME
    assert isa.bucket_of("sub.int") == isa.BUCKET_VPU_INT


def test_grouping_folds_modifiers():
    assert isa.group_class("log1p.f32") == "log.f32"
    assert isa.group_class("shift_left.int") == "shift.int"
    assert isa.group_class("exp.bf16") == "exp.bf16"


# ---------------------------------------------------------------------------
# Array-backed OpCounts: the vectorized currency.
# ---------------------------------------------------------------------------
def test_class_index_ids_are_stable_and_append_only():
    i1 = isa.CLASS_INDEX.intern("dot.bf16")
    assert isa.CLASS_INDEX.intern("dot.bf16") == i1
    n_before = len(isa.CLASS_INDEX)
    j = isa.CLASS_INDEX.intern("totally_new_op.f32")
    assert j >= n_before                      # appended, nothing reindexed
    assert isa.CLASS_INDEX.intern("dot.bf16") == i1
    assert isa.CLASS_INDEX.name(j) == "totally_new_op.f32"
    # bucket codes align with bucket_of
    codes = isa.CLASS_INDEX.bucket_codes()
    assert isa.BUCKET_ORDER[codes[i1]] == isa.BUCKET_MXU


def test_units_round_trips_through_dict_view():
    c = OpCounts()
    c.add("dot.bf16", 1e9)
    c.add("exp.f32", 5e5)
    c.add("weird_new_prim.f32", 3.0)         # interned raw class
    d = dict(c.units.items())
    back = OpCounts(units=d)
    assert back.units == c.units
    assert dict(back.units.items()) == d
    n = len(isa.CLASS_INDEX)
    np.testing.assert_array_equal(back.vector(n), c.vector(n))


def test_units_view_reads_like_defaultdict():
    c = OpCounts()
    c.add("add.f32", 7.0)
    assert c.units["add.f32"] == 7.0
    assert c.units["never_seen.f32"] == 0.0      # missing reads as 0.0
    assert c.units.get("never_seen.f32") is None
    assert "add.f32" in c.units and "mul.f32" not in c.units
    assert len(c.units) == 1 and list(c.units) == ["add.f32"]


def test_merge_and_scale_equal_elementwise_vector_arithmetic():
    x = OpCounts()
    x.add("add.f32", 3.0)
    x.add("dot.bf16", 10.0)
    y = OpCounts()
    y.add("add.f32", 4.0)
    y.add("exp.f32", 5.0)
    n = len(isa.CLASS_INDEX)
    want = x.vector(n) + 2.5 * y.vector(n)
    z = x.scaled(1.0)
    z.merge(y, 2.5)
    np.testing.assert_array_equal(z.vector(n), want)
    np.testing.assert_array_equal(x.scaled(3.0).vector(n), x.vector(n) * 3.0)


def test_units_dict_mutation_warns_once_and_redirects(monkeypatch):
    monkeypatch.setattr(counting, "_MUTATION_WARNED", False)
    c = OpCounts()
    with pytest.warns(DeprecationWarning, match="OpCounts.add"):
        c.units["add.f32"] = 9.0
    assert c.units["add.f32"] == 9.0             # write went through the index
    assert c.vector()[isa.CLASS_INDEX.id("add.f32")] == 9.0
    import warnings as _warnings
    with _warnings.catch_warnings(record=True) as record:  # warn-once
        _warnings.simplefilter("always")
        c.units["add.f32"] = 10.0
    assert not [w for w in record
                if issubclass(w.category, DeprecationWarning)]
    assert c.units["add.f32"] == 10.0


# ---------------------------------------------------------------------------
# jaxpr-vs-HLO front-end parity on a shared compiled fixture.
# ---------------------------------------------------------------------------
def test_jaxpr_and_hlo_counters_agree_on_compiled_fixture():
    from repro.hlo.opcount import count_hlo_text

    def fn(a, b):
        h = jnp.tanh(a @ b)
        return (h + 1.5).sum()

    args = (_sds((256, 512)), _sds((512, 128)))
    txt = jax.jit(fn).lower(*args).compile().as_text()
    cj = opcount.count_fn(fn, *args)
    ch = count_hlo_text(txt)
    # structural classes agree exactly: both front-ends price through the
    # shared core (counting.add_dot / group_class / add_reduce)
    assert ch.units["dot.f32"] == cj.units["dot.f32"] == 256 * 512 * 128
    assert ch.mxu_macs_total == cj.mxu_macs_total
    assert ch.mxu_macs_aligned == cj.mxu_macs_aligned
    assert ch.units["tanh.f32"] == cj.units["tanh.f32"]
    assert ch.units["add.f32"] == cj.units["add.f32"]
    # XLA may restructure reductions (reduce-window chains); totals stay close
    assert ch.units["reduce.add.f32"] == pytest.approx(
        cj.units["reduce.add.f32"], rel=0.05)
    assert ch.flops == pytest.approx(cj.flops, rel=0.01)
    # both observe the tanh+add chain as fused (VMEM-resident) traffic
    assert cj.fused_bytes > 0 and ch.fused_bytes > 0


def test_hlo_counter_has_no_private_accumulation():
    """The HLO front-end must price through the shared core: no local
    collective-wire table, dtype-grouping table, or MMA-form selection."""
    import inspect

    import repro.hlo.opcount as hlo_oc
    src = inspect.getsource(hlo_oc)
    assert "dot_group" not in src            # MMA selection is the core's
    assert "dot_small" not in src
    assert "(n - 1)" not in src              # wire formulas are the core's
    assert "_DTYPE_TAG = {" not in src       # dtype grouping is the core's
    for fn in ("add_dot", "add_conv", "add_collective", "merge_loop_body",
               "merge_best_branch", "add_reduce", "convert_class"):
        assert f"counting.{fn}" in src
