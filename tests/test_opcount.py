"""Unit tests for the jaxpr op counter (the profiler)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isa, opcount


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_scan_multiplies_counts():
    def fn(x):
        def body(c, _):
            return c + 1.0, ()
        c, _ = jax.lax.scan(body, x, None, length=37)
        return c
    c = opcount.count_fn(fn, _sds((8, 16)))
    assert c.units["add.f32"] == 37 * 8 * 16
    assert c.units["ctl.loop"] == 37


def test_dot_macs_and_alignment():
    def fn(a, b):
        return a @ b
    c = opcount.count_fn(fn, _sds((256, 512)), _sds((512, 128)))
    assert c.units["dot.f32"] == 256 * 512 * 128
    assert c.flops == 2 * 256 * 512 * 128
    assert c.mxu_macs_aligned == c.mxu_macs_total   # all dims %128 == 0

    c2 = opcount.count_fn(fn, _sds((100, 512)), _sds((512, 128)))
    assert c2.mxu_macs_aligned == 0                 # 100 not aligned


def test_batched_dot():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c = opcount.count_fn(fn, _sds((4, 32, 64)), _sds((4, 64, 16)))
    assert c.units["dot.f32"] == 4 * 32 * 64 * 16


def test_arch_gen_remaps_dot_forms():
    def small(a, b):
        return a @ b
    c0 = opcount.count_fn(small, _sds((16, 64)), _sds((64, 32)))
    assert "dot.f32" in c0.units
    c1 = opcount.count_fn(small, _sds((16, 64)), _sds((64, 32)), isa_gen=1)
    assert "dot_small.f32" in c1.units and "dot.f32" not in c1.units

    def batched(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    c2 = opcount.count_fn(batched, _sds((4, 256, 256)), _sds((4, 256, 256)),
                          isa_gen=2)
    assert "dot_group.f32" in c2.units


def test_convert_classes():
    def fn(x):
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    c = opcount.count_fn(fn, _sds((128, 128)))
    assert c.units["convert.f32.bf16"] == 128 * 128
    assert c.units["convert.bf16.f32"] == 128 * 128


def test_elementwise_dtype_grouping():
    def fn(x):
        return jnp.exp(x) + jnp.tanh(x)
    c = opcount.count_fn(fn, _sds((64, 64), jnp.bfloat16))
    assert c.units["exp.bf16"] == 64 * 64
    assert c.units["tanh.bf16"] == 64 * 64
    assert c.units["add.bf16"] == 64 * 64


def test_gather_io_only_touched_rows():
    def fn(table, idx):
        return table[idx]
    c = opcount.count_fn(fn, _sds((100000, 64)), _sds((32,), jnp.int32))
    # traffic ~ gathered rows (+ index bookkeeping), not the whole table
    assert c.naive_bytes < 3 * (32 * 64 * 4)
    assert c.units["gather"] == 32 * 64


def test_fusion_boundary_vs_fused():
    def chain(x):
        for _ in range(10):
            x = x * 1.5
        return x
    c = opcount.count_fn(chain, _sds((128, 128)))
    # 10-op chain: only first read + last write are boundary
    assert c.fused_bytes > 4 * c.boundary_bytes


def test_collective_wire_bytes_math():
    b = 1024.0
    assert opcount._COLLECTIVES["psum"][1](b, 8) == 2 * b * 7 / 8
    assert opcount._COLLECTIVES["all_gather"][1](b, 8) == b * 7
    assert opcount._COLLECTIVES["ppermute"][1](b, 8) == b


def test_cond_counts_worst_branch():
    def fn(x, p):
        return jax.lax.cond(p, lambda v: v @ v, lambda v: v + 1.0, x)
    c = opcount.count_fn(fn, _sds((64, 64)), _sds((), jnp.bool_))
    assert c.units.get("dot.f32", 0) == 64 * 64 * 64
    assert c.units["ctl.cond"] == 1


def test_unknown_class_reaches_bucketing():
    # sub.int has no table entry but must bucket as integer-lane work
    assert "sub.int" not in isa.CLASS_BY_NAME
    assert isa.bucket_of("sub.int") == isa.BUCKET_VPU_INT


def test_grouping_folds_modifiers():
    assert isa.group_class("log1p.f32") == "log.f32"
    assert isa.group_class("shift_left.int") == "shift.int"
    assert isa.group_class("exp.bf16") == "exp.bf16"
