"""Pallas kernels vs pure-jnp oracles: shape/dtype/feature sweeps in
interpret mode (kernel bodies execute in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.ssm import ssd_chunked_ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 128, 2, 64), (2, 256, 4, 64),
                                   (1, 512, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("feature", ["plain", "window", "softcap"])
def test_flash_attention_matches_ref(shape, dtype, feature):
    b, s, h, d = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], shape, dtype)
    k = jax.random.normal(ks[1], shape, dtype)
    v = jax.random.normal(ks[2], shape, dtype)
    kwargs = {"causal": True}
    if feature == "window":
        kwargs["window"] = s // 4
    if feature == "softcap":
        kwargs["softcap"] = 30.0
    out = ops.flash_attention(q, k, v, interpret=True, **kwargs)
    want = ref.flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_noncausal():
    b, s, h, d = 1, 256, 2, 64
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
    out = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kvh,g", [(1, 4), (2, 7), (4, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(kvh, g, dtype):
    b, s, d = 2, 1024, 64
    h = kvh * g
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, kvh, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, kvh, d), dtype)
    lengths = jnp.array([300, s], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lengths, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("chunk", [8, 16])
@pytest.mark.parametrize("hpn", [(2, 8, 16), (3, 16, 32)])
def test_ssd_kernel_matches_ref(chunk, hpn):
    h, p, n = hpn
    bsz, s = 2, 64
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, s, n))
    C = jax.random.normal(ks[4], (bsz, s, n))
    y1, st1 = ops.ssd_chunked(x, dt, a, B, C, chunk=chunk, interpret=True)
    y2, st2 = ssd_chunked_ref(x, dt, a, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=1e-4, atol=1e-4)


def test_ssd_single_chunk_against_oracle():
    bsz, l, h, p, n = 1, 16, 2, 8, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bsz, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, l, n))
    C = jax.random.normal(ks[4], (bsz, l, n))
    from repro.kernels.ssd_scan import ssd_chunk
    y, st = ssd_chunk(x, dt, a, B, C, interpret=True)
    y2, st2 = ref.ssd_chunk_ref(x, dt, a, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Non-divisible tails: S need not be a multiple of the block sizes.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s", [130, 200])
@pytest.mark.parametrize("feature", ["plain", "window", "softcap",
                                     "noncausal"])
def test_flash_attention_nondivisible_s(s, feature):
    b, h, d = 1, 2, 64
    ks = jax.random.split(KEY, 3)
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
    kwargs = {"causal": feature != "noncausal"}
    if feature == "window":
        kwargs["window"] = 48
    if feature == "softcap":
        kwargs["softcap"] = 30.0
    out = ops.flash_attention(q, k, v, interpret=True,
                              block_config=(64, 64), **kwargs)
    want = ref.flash_attention_ref(q, k, v, **kwargs)
    assert out.shape == (b, s, h, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("s", [700, 1000])
def test_decode_attention_nondivisible_s(s):
    kvh, g, d = 2, 3, 64
    b, h = 2, kvh * g
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kc = jax.random.normal(ks[1], (b, s, kvh, d))
    vc = jax.random.normal(ks[2], (b, s, kvh, d))
    lengths = jnp.array([s // 3, s], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lengths, interpret=True,
                               block_config=(256,))
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_ssd_nondivisible_s_matches_divisible_ref():
    bsz, s, h, p, n = 2, 100, 2, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, s, n))
    C = jax.random.normal(ks[4], (bsz, s, n))
    # kernel pads 100 -> 128 internally; the chunking itself is exact, so a
    # divisible-chunk reference is the oracle for both y and the final state
    y1, st1 = ops.ssd_chunked(x, dt, a, B, C, chunk=32, interpret=True)
    y2, st2 = ssd_chunked_ref(x, dt, a, B, C, 20)
    assert y1.shape == (bsz, s, h, p)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                               rtol=1e-4, atol=1e-4)


def test_divisible_path_takes_no_pad_branch():
    """At block-multiple S the tail machinery must stay out of the jaxpr —
    the bitwise-preservation claim for every pre-existing call site."""
    def fa(s):
        shape = (1, s, 2, 64)
        sd = jax.ShapeDtypeStruct(shape, jnp.float32)
        return str(jax.make_jaxpr(
            lambda q, k, v: ops.flash_attention(
                q, k, v, interpret=True, block_config=(64, 64)))(sd, sd, sd))
    assert "pad[" not in fa(128)
    assert "pad[" in fa(130)


def test_model_forward_with_pallas_attention():
    """attn_fn hook end-to-end: flash kernel inside the qwen2 smoke model."""
    import dataclasses
    from repro import configs as cfgs
    from repro.models import model as M
    cfg = dataclasses.replace(cfgs.get_smoke_config("qwen2-0.5b"),
                              dtype="float32", remat=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.arange(2 * 128, dtype=jnp.int32).reshape(2, 128) % cfg.vocab
    batch = {"tokens": tokens}
    base, _ = M.forward(params, batch, cfg)
    fast, _ = M.forward(params, batch, cfg,
                        attn_fn=ops.make_attn_fn(interpret=True))
    np.testing.assert_allclose(np.asarray(base), np.asarray(fast),
                               rtol=5e-3, atol=5e-3)
