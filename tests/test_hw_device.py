"""Simulated hardware substrate: determinism, physics, cooling."""
import numpy as np

from repro.core.opcount import OpCounts
from repro.hw.device import Program
from repro.hw.systems import SYSTEMS, get_device


def _counts(macs=5e9):
    c = OpCounts()
    c.add("dot.bf16", macs)
    c.mxu_macs_total = macs
    c.mxu_macs_aligned = macs
    c.boundary_read_bytes = c.boundary_write_bytes = 5e7
    c.naive_bytes = 1e8
    c.max_buffer_bytes = 5e7
    c.dispatch_count = 4
    return c


def _run_steady(system, name="p", seconds=120.0):
    dev = get_device(system)
    c = _counts()
    rec = dev.run(Program(name, c, iters=dev.iters_for_duration(c, seconds)))
    return rec


def test_deterministic_runs():
    a = _run_steady("sim-v5e-air")
    b = _run_steady("sim-v5e-air")
    assert a.energy_counter_j == b.energy_counter_j
    np.testing.assert_array_equal(a.trace.power_w, b.trace.power_w)


def test_energy_scales_with_work():
    e1 = _run_steady("sim-v5e-air", seconds=60.0)
    e2 = _run_steady("sim-v5e-air", seconds=120.0)
    assert 1.8 < e2.energy_counter_j / e1.energy_counter_j < 2.2


def test_liquid_cooling_reduces_energy():
    """Paper §5.2.1: water-cooled V100s used ~12% less energy."""
    air = _run_steady("sim-v5e-air", "wl")
    liq = _run_steady("sim-v5e-liquid", "wl")
    # same work (same iters since timing model is thermal-independent)
    assert air.iters == liq.iters
    rel = 1 - liq.energy_counter_j / air.energy_counter_j
    assert 0.04 < rel < 0.25


def test_newer_generation_more_efficient_per_work():
    a = _run_steady("sim-v5e-air", "g")
    b = _run_steady("sim-v6e-air", "g")
    per_work_5e = a.energy_counter_j / a.iters
    per_work_6e = b.energy_counter_j / b.iters
    assert per_work_6e < per_work_5e


def test_power_within_envelope():
    dev = get_device("sim-v5e-air")
    rec = _run_steady("sim-v5e-air", "big")
    assert np.max(rec.trace.power_w) < 1.25 * dev.chip.tdp_watts
    assert np.min(rec.trace.power_w) > 0.5 * dev.chip.idle_watts


def test_idle_draws_constant_power():
    dev = get_device("sim-v5e-air")
    tr = dev.idle(30.0)
    assert abs(np.median(tr.power_w) - dev._hidden.p_const) < 2.0


def test_all_systems_instantiate():
    for name in SYSTEMS:
        rec = get_device(name).run(Program("x", _counts(), iters=1000))
        assert rec.energy_counter_j > 0
