"""Per-architecture smoke tests (required): reduced config of the same
family, one forward + one train step on CPU, asserting shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.configs.base import ShapeSpec, token_inputs
from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.step import init_state, make_train_step

pytestmark = pytest.mark.slow   # heavy model/distributed tier

B, S = 2, 16


def _batch(cfg, with_targets=True):
    batch = {}
    rng = np.random.default_rng(0)
    for k, sds in token_inputs(cfg, ShapeSpec("t", S, B, "train"),
                               with_targets).items():
        if sds.dtype == jnp.int32:
            batch[k] = jnp.asarray(
                rng.integers(0, min(cfg.vocab, 100), sds.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(
                rng.standard_normal(sds.shape) * 0.02, sds.dtype)
    return batch


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = cfgs.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux = jax.jit(lambda p, b: M.forward(p, b, cfg))(
        params, _batch(cfg, with_targets=False))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_train_step_runs_and_finite(arch):
    cfg = cfgs.get_smoke_config(arch)
    opt_cfg = opt_mod.OptConfig(total_steps=10, warmup_steps=1)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", cfgs.ARCHS)
def test_decode_step_advances_cache(arch):
    cfg = cfgs.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, B, 32)
    if cfg.family == "encdec":
        from repro.models import encdec
        enc = jnp.zeros((B, cfg.n_audio_frames, cfg.d_model),
                        cfg.activation_dtype)
        ck, cv = encdec.prefill_cross_cache(params, enc, cfg)
        cache = dict(cache, cross_k=ck, cross_v=cv)
    tok = jnp.full((B, 1), 3, jnp.int32)
    logits, cache = jax.jit(lambda p, c, t: M.decode_step(p, c, t, cfg))(
        params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["pos"]) == 1


def test_loss_decreases_over_steps():
    cfg = dataclasses.replace(cfgs.get_smoke_config("qwen2-0.5b"),
                              dtype="float32")
    opt_cfg = opt_mod.OptConfig(lr=5e-3, total_steps=30, warmup_steps=2)
    state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0,))
    batch = _batch(cfg)   # overfit one batch
    losses = []
    for _ in range(15):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5


def test_gradient_accumulation_matches_full_batch():
    cfg = dataclasses.replace(cfgs.get_smoke_config("qwen2-0.5b"),
                              dtype="float32")
    opt_cfg = opt_mod.OptConfig(total_steps=10, warmup_steps=1)
    batch = _batch(cfg)
    s1 = init_state(cfg, opt_cfg, jax.random.PRNGKey(1))
    s2 = jax.tree.map(jnp.copy, s1)
    step1 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=1))
    step2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)
