"""Serving loop + HLO parser unit coverage."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfgs
from repro.hlo.collectives import _group_size, collective_bytes
from repro.hlo.parse import parse_hlo_text, shape_bytes
from repro.models import model as M
from repro.serve.step import greedy_generate


def test_greedy_generate_shapes_and_determinism():
    cfg = dataclasses.replace(cfgs.get_smoke_config("qwen2-0.5b"),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.array([[5, 9, 2, 7]], jnp.int32)
    out1 = greedy_generate(params, cfg, prompt, max_new=6, max_seq=16)
    out2 = greedy_generate(params, cfg, prompt, max_new=6, max_seq=16)
    assert out1.shape == (1, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :4]),
                                  np.asarray(prompt))


HLO_SNIPPET = """
HloModule test, entry_computation_layout={()->f32[]}

%wide.body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %ar = f32[8,16]{1,0} all-reduce(%gte), replica_groups=[4,2]<=[8], to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %ar)
}

%wide.cond (p: (s32[], f32[8,16])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[] {
  %w = (s32[], f32[8,16]) while(%init), condition=%wide.cond, body=%wide.body
  %ag = f32[8,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={1}
  ROOT %r = f32[] constant(0)
}
"""


def test_hlo_parser_trips_and_groups():
    st = collective_bytes(HLO_SNIPPET)
    assert st.while_trips.get("wide.body") == 12.0 \
        or st.while_trips.get("%wide.body") == 12.0
    # all-reduce inside the loop: 12 executions, group size 2
    ar = st.by_kind["all-reduce"]
    assert abs(ar - 12 * 2 * (8 * 16 * 4) * (2 - 1) / 2) < 1e-6
    ag = st.by_kind["all-gather"]
    assert abs(ag - (8 * 128 * 4) * 3 / 4) < 1e-6


def test_group_size_formats():
    assert _group_size("replica_groups=[16,16]<=[256]") == 16
    assert _group_size("replica_groups={{0,1,2,3}}") == 4


def test_shape_bytes_tuple():
    assert shape_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2


def test_parse_entry_with_index_comments():
    mod = parse_hlo_text(HLO_SNIPPET)
    assert mod.entry == "main"
    assert "wide.body" in mod.computations
