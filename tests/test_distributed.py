"""Multi-device tests (8 host CPU devices via subprocess — jax locks the
device count at first init, so each scenario runs in its own process)."""
import subprocess
import sys
import textwrap
import os

import pytest

pytestmark = pytest.mark.slow   # heavy model/distributed tier

ENV = dict(os.environ,
           XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_compressed_psum_close_to_exact():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import compressed_psum

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 512))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                       out_specs=P("data", None))
    def mean_compressed(xl):
        return compressed_psum(xl / 8.0, "data")

    got = mean_compressed(x)
    want = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(got - want)))
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    assert err / scale < 0.02, (err, scale)
    print("ok", err)
    """)


def test_error_feedback_converges():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.parallel.compression import make_error_feedback

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))
    step = make_error_feedback()
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256)) * 0.01

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("data", None), P("data", None)),
                       out_specs=(P("data", None), P("data", None)))
    def run(xl, res):
        out, new_res = step(xl, res, "data")
        return out, new_res

    res = jnp.zeros_like(x)
    acc_c = jnp.zeros((1, 256))
    acc_t = jnp.zeros((1, 256))
    for i in range(30):
        out, res = run(x, res)
        acc_c = acc_c + out[:1]
        acc_t = acc_t + jnp.sum(x, 0, keepdims=True)
    # error feedback: accumulated compressed sums track the true sums
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.01, rel
    print("ok", rel)
    """)


def test_pjit_train_step_on_mesh_and_elastic_reshard():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs as cfgs
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.models.layers import sds_from_specs
    from repro.parallel import sharding as sh
    from repro.train import optimizer as opt_mod
    from repro.train.elastic import reshard
    from repro.train.step import init_state, make_train_step

    cfg = cfgs.get_smoke_config("qwen2-0.5b")
    mesh = make_mesh((2, 4), ("data", "model"))
    opt_cfg = opt_mod.OptConfig()
    specs = M.model_specs(cfg)
    with mesh:
        state = init_state(cfg, opt_cfg, jax.random.PRNGKey(0))
        state = jax.device_put(
            state.params, sh.param_shardings(specs, mesh)), state.opt
        from repro.train.step import TrainState
        state = TrainState(params=state[0], opt=state[1])
        step = jax.jit(make_train_step(cfg, opt_cfg))
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "targets": jnp.zeros((8, 16), jnp.int32)}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))

    # elastic: shrink to a 4-device mesh, step again
    mesh2 = make_mesh((1, 4), ("data", "model"))
    with mesh2:
        p2 = reshard(jax.device_get(state.params), specs, mesh2)
        from repro.train.optimizer import init_opt_state
        state2 = TrainState(params=p2, opt=init_opt_state(p2, opt_cfg))
        step2 = jax.jit(make_train_step(cfg, opt_cfg))
        state2, m2 = step2(state2, batch)
        assert np.isfinite(float(m2["loss"]))
    print("ok")
    """)


def test_hlo_collective_accounting_on_real_compile():
    _run("""
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.hlo import collective_bytes
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))

    def step(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), ()
        h, _ = jax.lax.scan(body, x, ws)
        return h.sum()

    xs = jax.ShapeDtypeStruct((256, 512), jnp.float32,
                              sharding=NamedSharding(mesh, P("data", None)))
    ws = jax.ShapeDtypeStruct((6, 512, 512), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, None,
                                                             "model")))
    compiled = jax.jit(step).lower(xs, ws).compile()
    st = collective_bytes(compiled.as_text())
    # the scanned loop body must be multiplied by its trip count (6)
    assert any(abs(v - 6.0) < 0.5 for v in st.while_trips.values()), \\
        st.while_trips
    assert st.wire_bytes_per_chip > 0
    print("ok", st.by_kind)
    """)


def test_pipeline_parallel_matches_sequential():
    _run("""
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_mesh
    from repro.parallel.pipeline import pipeline_forward
    mesh = make_mesh((4,), ("stage",))
    S, M, mb, d = 4, 6, 2, 8
    ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.3
    def stage_fn(w, x):
        return jnp.tanh(x @ w)
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    out = pipeline_forward(stage_fn, ws, xs, mesh)
    ref = xs
    for s in range(S):
        ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, err
    print("ok", err)
    """)


def test_dryrun_variants_build_on_small_mesh():
    _run("""
    import jax
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_mesh
    from repro.core.opcount import count_fn
    mesh = make_mesh((2, 4), ("data", "model"))
    for variant in ("baseline", "zero1", "moe-index", "serve-repl"):
        for arch, shape in (("qwen2-0.5b", "train_4k"),
                            ("arctic-480b", "decode_32k")):
            fn, args, mf = build_cell(arch, shape, mesh, variant=variant)
            c = count_fn(fn, *args)
            assert c.flops > 0
    print("ok")
    """)


def test_opcount_shard_map_collectives():
    _run("""
    import jax, jax.numpy as jnp, functools
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.opcount import count_fn

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("data",))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("data", None),
                       out_specs=P("data", None))
    def fn(x):
        return jax.lax.psum(x, "data")

    c = count_fn(fn, jax.ShapeDtypeStruct((8, 1024), jnp.float32))
    want = 2 * (1024 * 4) * 7 / 8     # 2(n-1)/n x local bytes
    got = c.units.get("ici.all_reduce", 0.0)
    assert abs(got - want) / want < 0.01, (got, want)
    print("ok", got)
    """)
