"""J/op autotuner + kernel energy table: search, persistence, "auto" path.

Acceptance criteria covered here:
  (a) successive halving lands on the exhaustive-search optimum (the grids
      are small enough that the halving path must not lose the winner);
  (b) the winner never prices worse than the shipped default under the
      shared protocol (the default is pinned into the final round);
  (c) the ``KernelEnergyTable`` tier round-trips through the ``TableStore``
      and ``best()`` honors variant/point/latency filters;
  (d) ``block_config="auto"`` with no tuned entry builds bit-for-bit the
      same result as the shipped defaults, and picks the winner once a
      table is active.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.kernel_table import (KernelEnergyTable, KernelEntry,
                                     KernelTableError)
from repro.core.store import TableStore
from repro.hw.systems import get_device
from repro.kernels import autotune, ops

FAST = dict(durations=(2.0, 4.0), repeats=(1, 1))


@pytest.fixture(autouse=True)
def _isolate_active_table():
    old = autotune.get_active()
    autotune.set_active(None)
    yield
    autotune.set_active(old)


def _entry(kernel="flash_attention", variant="pallas", config=(128, 128),
           point=None, j_per_op=1e-11, latency_s=1e-3) -> KernelEntry:
    return KernelEntry(kernel=kernel, variant=variant, config=tuple(config),
                       point=point, j_per_op=j_per_op, j_per_call=j_per_op,
                       latency_s=latency_s, ops_per_call=1.0,
                       energy_j=1.0, duration_s=1.0, iters=1,
                       spec_id=f"t:{kernel}:{variant}:{config}:{point}")


# ---------------------------------------------------------------------------
# (a) + (b): the search itself.
# ---------------------------------------------------------------------------
def test_halving_matches_exhaustive_and_beats_default():
    device = get_device("sim-v5e-air")
    halved = autotune.tune("ssd_chunked", device, **FAST)
    oracle = autotune.tune("ssd_chunked", device, exhaustive=True, **FAST)
    assert halved.winner.key == oracle.winner.key
    assert halved.winner.j_per_op == oracle.winner.j_per_op
    assert halved.winner.j_per_op <= halved.default.j_per_op
    assert halved.improvement >= 0.0
    # the default was re-measured in the final round, same protocol
    assert halved.default.variant == "pallas"
    assert tuple(halved.default.config) == \
        autotune.SEARCH_SPACES["ssd_chunked"].default
    # rounds narrow: the final round holds no more candidates than the first
    assert len(halved.rounds[-1]) <= len(halved.rounds[0])


def test_tune_unknown_kernel_rejected():
    with pytest.raises(KeyError, match="unknown kernel"):
        autotune.tune("warp_drive", get_device("sim-v5e-air"))


def test_latency_ceiling_constrains_winner():
    device = get_device("sim-v5e-air")
    free = autotune.tune("ssd_chunked", device, **FAST)
    tight = autotune.tune("ssd_chunked", device,
                          latency_ceiling_s=free.winner.latency_s * 0.5,
                          **FAST)
    assert all(e.j_per_op >= tight.winner.j_per_op or
               e.latency_s > free.winner.latency_s * 0.5
               for e in tight.entries)


def test_record_cache_resumes_bitwise(tmp_path):
    device = get_device("sim-v5e-air")
    first = autotune.tune("ssd_chunked", device, run_dir=tmp_path, **FAST)
    assert list(tmp_path.glob("records/*.json"))
    again = autotune.tune("ssd_chunked", device, run_dir=tmp_path, **FAST)
    assert again.winner.j_per_op == first.winner.j_per_op
    assert again.default.energy_j == first.default.energy_j
    # and a fresh campaign without records reproduces the same numbers:
    # sensor noise draws from deterministic per-(spec, repeat) substreams
    fresh = autotune.tune("ssd_chunked", device, **FAST)
    assert fresh.winner.j_per_op == first.winner.j_per_op


# ---------------------------------------------------------------------------
# (c): the kernel table tier.
# ---------------------------------------------------------------------------
def test_kernel_table_round_trips_through_store(tmp_path):
    store = TableStore(tmp_path)
    assert store.get_kernel_table("sys") is None
    kt = KernelEnergyTable("sys")
    kt.put(_entry(config=(128, 128), j_per_op=2e-11))
    kt.put(_entry(config=(256, 256), j_per_op=1e-11))
    kt.put(_entry(variant="ref", config=(), j_per_op=5e-12))
    path = store.put_kernel_table(kt)
    assert path.exists()
    loaded = store.get_kernel_table("sys")
    assert len(loaded) == 3
    assert loaded.get("flash_attention", "pallas", (256, 256)).j_per_op \
        == 1e-11
    # best() semantics: the ref entry wins outright, the pallas filter
    # excludes it, a latency ceiling excludes everything too slow
    assert loaded.best("flash_attention").variant == "ref"
    best_pallas = loaded.best("flash_attention", variant="pallas")
    assert best_pallas.config == (256, 256)
    assert loaded.best("flash_attention", variant="pallas",
                       latency_ceiling_s=1e-9) is None


def test_kernel_table_point_fallback():
    kt = KernelEnergyTable("sys")
    kt.put(_entry(config=(128, 128), j_per_op=3e-11, point=None))
    kt.put(_entry(config=(256, 256), j_per_op=1e-11, point="f800c150"))
    assert kt.best("flash_attention", point="f800c150").config == (256, 256)
    # unseen point: nominal entries answer rather than nothing
    assert kt.best("flash_attention", point="f123c45").config == (128, 128)


def test_kernel_table_schema_guard():
    with pytest.raises(KernelTableError):
        KernelEnergyTable.from_dict({"schema": 99, "system": "sys",
                                     "entries": []})
    kt = KernelEnergyTable.from_dict(KernelEnergyTable("sys").to_dict())
    assert kt.system == "sys" and len(kt) == 0


def test_tune_and_store_persists_and_activates(tmp_path):
    store = TableStore(tmp_path)
    device = get_device("sim-v5e-air")
    res = autotune.tune_and_store("ssd_chunked", device, "sim-v5e-air",
                                  store=store, **FAST)
    kt = store.get_kernel_table("sim-v5e-air")
    assert kt is not None
    assert kt.get(*res.winner.key) is not None
    active = autotune.get_active()
    assert active is not None and active.get(*res.winner.key) is not None
    assert autotune.best_config("ssd_chunked") == res.winner.config
    # a second campaign for another kernel merges, not overwrites
    autotune.tune_and_store("decode_attention", device, "sim-v5e-air",
                            store=store, **FAST)
    merged = store.get_kernel_table("sim-v5e-air")
    assert merged.entries("ssd_chunked") and \
        merged.entries("decode_attention")


# ---------------------------------------------------------------------------
# (d): the "auto" lookup behind the kernel entry points.
# ---------------------------------------------------------------------------
def test_best_config_empty_cases():
    assert autotune.best_config("flash_attention") is None   # no table
    kt = KernelEnergyTable("sys")
    kt.put(_entry(variant="ref", config=()))
    autotune.set_active(kt)
    assert autotune.best_config("flash_attention") is None   # ref-only
    assert autotune.best_config("decode_attention") is None  # no entry


def test_block_config_auto_without_entry_is_bitwise_default():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 128, 2, 64)) for kk in ks)
    base = ops.flash_attention(q, k, v, interpret=True)
    auto = ops.flash_attention(q, k, v, interpret=True, block_config="auto")
    assert (np.asarray(base) == np.asarray(auto)).all()
    with pytest.raises(ValueError, match="block_config"):
        ops.flash_attention(q, k, v, interpret=True, block_config="fastest")
    with pytest.raises(ValueError, match="needs 2"):
        ops.flash_attention(q, k, v, interpret=True, block_config=(64,))


def test_block_config_auto_reads_active_winner():
    kt = KernelEnergyTable("sys")
    kt.put(_entry(kernel="flash_attention", config=(64, 64)))
    kt.put(_entry(kernel="decode_attention", config=(128,)))
    kt.put(_entry(kernel="ssd_chunked", config=(32,)))
    autotune.set_active(kt)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (1, 128, 2, 64)) for kk in ks)
    tuned = ops.flash_attention(q, k, v, interpret=True, block_config="auto")
    explicit = ops.flash_attention(q, k, v, interpret=True,
                                   block_config=(64, 64))
    assert (np.asarray(tuned) == np.asarray(explicit)).all()
    # ssd: the tuned chunk overrides the keyword default
    import jax.numpy as jnp
    x = jax.random.normal(ks[0], (1, 64, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 2)))
    a = -jnp.ones((2,))
    bm = jax.random.normal(ks[2], (1, 64, 8))
    y_auto, _ = ops.ssd_chunked(x, dt, a, bm, bm, interpret=True,
                                block_config="auto")
    y_32, _ = ops.ssd_chunked(x, dt, a, bm, bm, chunk=32, interpret=True)
    assert (np.asarray(y_auto) == np.asarray(y_32)).all()


def test_tune_result_improvement_sign():
    worse = dataclasses.replace(_entry(config=(999, 999)), j_per_op=4e-11)
    res = autotune.KernelTuneResult(
        kernel="flash_attention", winner=_entry(j_per_op=1e-11),
        default=worse, entries=[], rounds=[])
    assert res.improvement == pytest.approx(0.75)
